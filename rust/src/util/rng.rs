//! Deterministic PRNG + distribution samplers.
//!
//! The offline registry has no `rand` crate, so we implement PCG32
//! (O'Neill 2014) plus the samplers the simulator needs: uniform, normal
//! (Box–Muller), lognormal, exponential (Poisson inter-arrival gaps), and a
//! quantized normal for the S3-store component. All experiments are seeded,
//! so every table/figure in EXPERIMENTS.md is bit-reproducible.

/// PCG32: 64-bit state, 32-bit output, period 2^64 per stream.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
    /// cached second Box–Muller variate
    gauss_cache: Option<f64>,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Create a generator from a seed and stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = (stream << 1) | 1;
        let mut rng = Pcg32 { state: 0, inc, gauss_cache: None };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(inc);
        rng.state = rng.state.wrapping_add(seed);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(inc);
        rng
    }

    /// Convenience constructor with stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Next raw 32-bit output.
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next u64 from two 32-bit draws.
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1) with 53-bit precision.
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn uniform_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    pub fn uniform_usize(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // rejection-free for our small n; modulo bias is negligible vs 2^64
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal_std(&mut self) -> f64 {
        if let Some(v) = self.gauss_cache.take() {
            return v;
        }
        // avoid ln(0)
        let u1 = loop {
            let u = self.uniform();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.gauss_cache = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with mean/sigma.
    pub fn normal(&mut self, mean: f64, sigma: f64) -> f64 {
        mean + sigma * self.normal_std()
    }

    /// Normal clamped below at `lo`.
    pub fn normal_min(&mut self, mean: f64, sigma: f64, lo: f64) -> f64 {
        self.normal(mean, sigma).max(lo)
    }

    /// Lognormal: exp(N(mu, sigma)).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal(mu, sigma).exp()
    }

    /// Exponential with rate lambda (mean 1/lambda). Poisson-process gaps.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        let u = loop {
            let u = self.uniform();
            if u > 1e-300 {
                break u;
            }
        };
        -u.ln() / lambda
    }

    /// Normal rounded to multiples of `q` and clamped at 0 (S3 store model).
    pub fn quantized_normal(&mut self, mean: f64, sigma: f64, q: f64) -> f64 {
        ((self.normal(mean, sigma) / q).round() * q).max(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Pcg32::new(42, 7);
        let mut b = Pcg32::new(42, 7);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg32::seeded(1);
        let mut b = Pcg32::seeded(2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn different_streams_differ() {
        let mut a = Pcg32::new(1, 0);
        let mut b = Pcg32::new(1, 1);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_in_unit_interval_and_covers() {
        let mut rng = Pcg32::seeded(3);
        let mut lo = f64::MAX;
        let mut hi: f64 = 0.0;
        for _ in 0..10_000 {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            lo = lo.min(u);
            hi = hi.max(u);
        }
        assert!(lo < 0.01 && hi > 0.99);
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg32::seeded(4);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal(10.0, 3.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 10.0).abs() < 0.05, "mean {mean}");
        assert!((var.sqrt() - 3.0).abs() < 0.05, "std {}", var.sqrt());
    }

    #[test]
    fn lognormal_median() {
        let mut rng = Pcg32::seeded(5);
        let n = 100_000;
        let mut xs: Vec<f64> = (0..n).map(|_| rng.lognormal(2.0, 0.5)).collect();
        xs.sort_by(f64::total_cmp);
        let median = xs[n / 2];
        assert!((median - 2.0f64.exp()).abs() / 2.0f64.exp() < 0.03);
    }

    #[test]
    fn exponential_mean() {
        let mut rng = Pcg32::seeded(6);
        let n = 100_000;
        let mean = (0..n).map(|_| rng.exponential(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn quantized_normal_grid() {
        let mut rng = Pcg32::seeded(7);
        for _ in 0..1000 {
            let v = rng.quantized_normal(550.0, 150.0, 100.0);
            assert!(v >= 0.0);
            assert!((v / 100.0 - (v / 100.0).round()).abs() < 1e-9);
        }
    }

    #[test]
    fn uniform_usize_bounds() {
        let mut rng = Pcg32::seeded(8);
        for _ in 0..1000 {
            assert!(rng.uniform_usize(7) < 7);
        }
    }
}
