//! Tiny numeric-CSV reader for the `artifacts/*_eval.csv` replay tables.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

/// A headerful, all-numeric CSV table held column-major.
#[derive(Debug, Clone)]
pub struct Table {
    pub headers: Vec<String>,
    pub columns: Vec<Vec<f64>>,
    index: HashMap<String, usize>,
}

impl Table {
    pub fn parse(text: &str) -> Result<Table> {
        let mut lines = text.lines().filter(|l| !l.trim().is_empty());
        let header_line = lines.next().context("empty csv")?;
        let headers: Vec<String> = header_line.split(',').map(|s| s.trim().to_string()).collect();
        let ncol = headers.len();
        let mut columns = vec![Vec::new(); ncol];
        for (lineno, line) in lines.enumerate() {
            let mut n = 0;
            for (j, cell) in line.split(',').enumerate() {
                if j >= ncol {
                    bail!("row {} has more than {} columns", lineno + 2, ncol);
                }
                let v: f64 = cell
                    .trim()
                    .parse()
                    .with_context(|| format!("row {} col {}: bad number `{}`", lineno + 2, j, cell))?;
                columns[j].push(v);
                n += 1;
            }
            if n != ncol {
                bail!("row {} has {} columns, expected {}", lineno + 2, n, ncol);
            }
        }
        let index = headers
            .iter()
            .enumerate()
            .map(|(i, h)| (h.clone(), i))
            .collect();
        Ok(Table { headers, columns, index })
    }

    pub fn load(path: &str) -> Result<Table> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        Table::parse(&text)
    }

    pub fn n_rows(&self) -> usize {
        self.columns.first().map_or(0, |c| c.len())
    }

    pub fn col(&self, name: &str) -> &[f64] {
        let i = *self
            .index
            .get(name)
            // detlint: allow(panic-path) — schema accessor: a checked-in artifact table missing a column is unrecoverable
            .unwrap_or_else(|| panic!("csv has no column `{name}`"));
        &self.columns[i]
    }

    pub fn has_col(&self, name: &str) -> bool {
        self.index.contains_key(name)
    }

    pub fn get(&self, name: &str, row: usize) -> f64 {
        self.col(name)[row]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_and_indexes() {
        let t = Table::parse("a,b,c\n1,2,3\n4,5.5,-6e1\n").unwrap();
        assert_eq!(t.n_rows(), 2);
        assert_eq!(t.col("b"), &[2.0, 5.5]);
        assert_eq!(t.get("c", 1), -60.0);
        assert!(t.has_col("a") && !t.has_col("z"));
    }

    #[test]
    fn rejects_ragged_rows() {
        assert!(Table::parse("a,b\n1\n").is_err());
        assert!(Table::parse("a,b\n1,2,3\n").is_err());
        assert!(Table::parse("a,b\n1,x\n").is_err());
        assert!(Table::parse("").is_err());
    }

    #[test]
    fn skips_blank_lines() {
        let t = Table::parse("a\n1\n\n2\n\n").unwrap();
        assert_eq!(t.col("a"), &[1.0, 2.0]);
    }
}
