//! Small statistics toolkit used by metrics aggregation and the benches.

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation; 0.0 for fewer than 2 samples.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Linear-interpolated percentile, p in [0, 100]. NaN-free input required.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    let mut v = xs.to_vec();
    // `total_cmp`-equal f64s are bitwise identical: unstable sort is safe
    v.sort_unstable_by(f64::total_cmp);
    percentile_sorted(&v, p)
}

/// Percentile over an already-sorted slice — callers taking several
/// percentiles of one large sample sort once and reuse it.
pub fn percentile_sorted(v: &[f64], p: f64) -> f64 {
    if v.is_empty() {
        return 0.0;
    }
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Mean absolute percentage error (%), guarding tiny denominators.
pub fn mape(actual: &[f64], predicted: &[f64]) -> f64 {
    assert_eq!(actual.len(), predicted.len());
    if actual.is_empty() {
        return 0.0;
    }
    let s: f64 = actual
        .iter()
        .zip(predicted)
        .map(|(a, p)| (a - p).abs() / a.abs().max(1e-9))
        .sum();
    s / actual.len() as f64 * 100.0
}

/// Absolute percentage error between two scalars (%).
pub fn ape(actual: f64, predicted: f64) -> f64 {
    (actual - predicted).abs() / actual.abs().max(1e-12) * 100.0
}

/// Welford online mean/variance accumulator.
#[derive(Debug, Clone, Default)]
pub struct Online {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Online {
    pub fn new() -> Self {
        Online { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.mean }
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / self.n as f64 }
    }

    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.min }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.max }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_slices_are_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(mape(&[], &[]), 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_sorted_agrees_with_unsorted() {
        let xs = [9.0, 1.0, 5.0, 3.0, 7.0];
        let mut v = xs.to_vec();
        v.sort_unstable_by(f64::total_cmp);
        for p in [0.0, 25.0, 50.0, 90.0, 100.0] {
            assert_eq!(percentile(&xs, p), percentile_sorted(&v, p));
        }
    }

    #[test]
    fn mape_matches_hand_calc() {
        let a = [100.0, 200.0];
        let p = [110.0, 180.0];
        assert!((mape(&a, &p) - 10.0).abs() < 1e-12);
        assert!((ape(100.0, 93.0) - 7.0).abs() < 1e-12);
    }

    #[test]
    fn online_matches_batch() {
        let xs: Vec<f64> = (0..1000).map(|i| (i as f64 * 0.37).sin() * 3.0 + 5.0).collect();
        let mut o = Online::new();
        for &x in &xs {
            o.push(x);
        }
        assert!((o.mean() - mean(&xs)).abs() < 1e-9);
        assert!((o.std_dev() - std_dev(&xs)).abs() < 1e-9);
        assert_eq!(o.count(), 1000);
        assert!(o.min() <= o.mean() && o.mean() <= o.max());
    }
}
