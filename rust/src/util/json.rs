//! Minimal JSON parser/serializer (serde is unavailable offline).
//!
//! Supports the full JSON grammar needed by `artifacts/meta.json` and the
//! result files the experiment harness writes: objects, arrays, strings with
//! escapes, numbers, booleans, null. Numbers are held as f64 (meta.json only
//! carries f64-representable values).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, Clone)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

/// Compact (single-line) serialization — the JSONL form the event
/// recorder writes. `to_string()` (via `ToString`) yields one line with
/// no internal newlines.
impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        f.write_str(&out)
    }
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -------- accessors --------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object member lookup that panics with a useful message; for schema
    /// fields that must exist in meta.json.
    pub fn req(&self, key: &str) -> &Json {
        self.get(key)
            // detlint: allow(panic-path) — schema accessor: a missing meta.json key is unrecoverable
            .unwrap_or_else(|| panic!("missing required json key `{key}`"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn f64(&self) -> f64 {
        // detlint: allow(panic-path) — schema accessor twin of `as_f64`; see `req`
        self.as_f64().expect("expected json number")
    }

    pub fn usize(&self) -> usize {
        self.f64() as usize
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn str(&self) -> &str {
        // detlint: allow(panic-path) — schema accessor twin of `as_str`; see `req`
        self.as_str().expect("expected json string")
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn arr(&self) -> &[Json] {
        // detlint: allow(panic-path) — schema accessor twin of `as_arr`; see `req`
        self.as_arr().expect("expected json array")
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn obj(&self) -> &BTreeMap<String, Json> {
        // detlint: allow(panic-path) — schema accessor twin of `as_obj`; see `req`
        self.as_obj().expect("expected json object")
    }

    /// Array of numbers to Vec<f64>.
    pub fn f64_vec(&self) -> Vec<f64> {
        self.arr().iter().map(|v| v.f64()).collect()
    }

    /// Array of numbers to Vec<f32>.
    pub fn f32_vec(&self) -> Vec<f32> {
        self.arr().iter().map(|v| v.f64() as f32).collect()
    }

    // -------- serialization --------

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&" ".repeat(indent + 1));
                    }
                    e.write(out, indent + 1, pretty);
                }
                if pretty && !v.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent));
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, e)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&" ".repeat(indent + 1));
                    }
                    write_escaped(out, k);
                    out.push_str(if pretty { ": " } else { ":" });
                    e.write(out, indent + 1, pretty);
                }
                if pretty && !m.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent));
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s =
            std::str::from_utf8(&self.b[start..self.i]).map_err(|_| self.err("bad number"))?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{0008}'),
                        Some(b'f') => s.push('\u{000C}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full UTF-8 char
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let Some(c) = rest.chars().next() else {
                        return Err(self.err("invalid utf-8"));
                    };
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x", "c": null}], "d": 1e-3}"#).unwrap();
        assert_eq!(v.req("d").f64(), 1e-3);
        let arr = v.req("a").arr();
        assert_eq!(arr[1].f64(), 2.0);
        assert_eq!(arr[2].req("b").str(), "x");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("[1] x").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("\"abc").is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(Json::parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
        assert_eq!(Json::parse("\"héllo\"").unwrap(), Json::Str("héllo".into()));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"apps": {"ir": {"x": [1.5, -2, 3e6], "name": "i\"r"}}, "n": 19}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn real_meta_json_parses() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/meta.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let meta = Json::parse(&text).unwrap();
            assert_eq!(meta.req("memory_configs_mb").arr().len(), 19);
            assert!(meta.req("apps").get("fd").is_some());
        }
    }

    #[test]
    fn compact_roundtrip_single_line() {
        let src = r#"{"apps": {"ir": {"x": [1.5, -2, 3e6], "name": "i\"r"}}, "n": 19}"#;
        let v = Json::parse(src).unwrap();
        let line = v.to_string();
        assert!(!line.contains('\n'));
        assert_eq!(Json::parse(&line).unwrap(), v);
    }

    #[test]
    fn f64_vec_helper() {
        let v = Json::parse("[1, 2.5, 3]").unwrap();
        assert_eq!(v.f64_vec(), vec![1.0, 2.5, 3.0]);
    }
}
