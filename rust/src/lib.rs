//! # skedge — dynamic task placement for edge-cloud serverless platforms
//!
//! Reproduction of Das, Imai, Patterson & Wittie, *Performance Optimization
//! for Edge-Cloud Serverless Platforms via Dynamic Task Placement* (2020).
//!
//! Three layers:
//!  * **L3 (this crate)** — the coordinator: Predictor + CIL, Decision
//!    Engine, event-driven simulator, threaded live prototype, AWS substrate
//!    simulator, experiment harness.
//!  * **L2** — the JAX prediction graph (`python/compile/model.py`),
//!    AOT-lowered to HLO text artifacts loaded by [`runtime`].
//!  * **L1** — the Pallas GBRT forest-evaluation kernel
//!    (`python/compile/kernels/gbrt.py`).
//!
//! Beyond the paper's single-device protocol, [`fleet`] scales the same
//! question to thousands of devices sharing regional container pools, and
//! [`region`] spans them across a multi-region cloud topology with routed
//! placement and fleet-aware (hub-CIL) warm prediction.
//!
//! See the top-level README.md for the crate layout and how to run each
//! subsystem.

pub mod benchkit;
pub mod cli;
pub mod config;
pub mod fabric;
pub mod fleet;
pub mod live;
pub mod testkit;
pub mod engine;
pub mod experiments;
pub mod metrics;
pub mod obs;
pub mod predictor;
pub mod region;
pub mod runtime;
pub mod sim;
pub mod models;
pub mod platform;
pub mod util;
pub mod workload;
