//! Per-task records and the aggregations behind every table in the paper's
//! evaluation (Tables III, IV, V and Figs. 5, 6).

use crate::predictor::Placement;
use crate::util::stats;

/// Everything recorded about one processed task.
#[derive(Debug, Clone)]
pub struct TaskRecord {
    pub id: usize,
    pub arrive_ms: f64,
    pub placement: Placement,
    pub predicted_e2e_ms: f64,
    pub actual_e2e_ms: f64,
    pub predicted_cost: f64,
    pub actual_cost: f64,
    /// cost cap applied at decision time (lat-min; ∞ for cost-min)
    pub allowed_cost: f64,
    /// engine found a constraint-satisfying configuration
    pub feasible_found: bool,
    /// cloud only: did the Predictor's CIL call warm, and was it warm?
    pub warm_predicted: Option<bool>,
    pub warm_actual: Option<bool>,
    /// edge only: time spent waiting in the Executor FIFO
    pub edge_wait_ms: f64,
    /// admission denied everywhere the task was tried: it never executed.
    /// Rejected tasks are counted in summaries but excluded from latency
    /// percentiles and averages (their e2e/cost fields are zero).
    pub rejected: bool,
    /// inter-region failover hops taken before the task was served (or
    /// finally rejected)
    pub failover_hops: u32,
    /// extra one-way routing latency accumulated by failover hops (ms);
    /// part of `actual_e2e_ms` for served tasks
    pub failover_routing_ms: f64,
    /// admission queue wait under `ThrottlePolicy::Queue` (ms); part of
    /// `actual_e2e_ms` for served tasks
    pub throttle_wait_ms: f64,
}

impl TaskRecord {
    pub fn is_edge(&self) -> bool {
        self.placement == Placement::Edge
    }

    /// Executed somewhere (edge or cloud) — i.e. not throttled-rejected.
    pub fn is_served(&self) -> bool {
        !self.rejected
    }

    pub fn warm_cold_mismatch(&self) -> bool {
        matches!((self.warm_predicted, self.warm_actual), (Some(p), Some(a)) if p != a)
    }
}

/// Aggregated run metrics — one per simulation / live run.
#[derive(Debug, Clone)]
pub struct Summary {
    /// all records, served and rejected
    pub n: usize,
    /// throttled-rejected tasks: counted here, excluded from every latency
    /// / cost aggregate below (the remaining fields describe served tasks)
    pub rejected_count: usize,
    /// failover hops summed over all records
    pub failover_hops: u64,
    pub total_actual_cost: f64,
    pub total_predicted_cost: f64,
    pub avg_actual_e2e_ms: f64,
    pub avg_predicted_e2e_ms: f64,
    pub edge_count: usize,
    pub cloud_count: usize,
    pub warm_cold_mismatches: usize,
    pub cloud_actual_warm: usize,
    pub cloud_actual_cold: usize,
}

impl Summary {
    pub fn from_records(records: &[TaskRecord]) -> Summary {
        let n = records.len();
        // all aggregates below run over served records only; with zero
        // rejections the filter is an order-preserving no-op, which keeps
        // the no-capacity paths bit-identical to the paper protocol
        let served = || records.iter().filter(|r| r.is_served());
        Summary {
            n,
            rejected_count: records.iter().filter(|r| r.rejected).count(),
            failover_hops: records.iter().map(|r| r.failover_hops as u64).sum(),
            total_actual_cost: served().map(|r| r.actual_cost).sum(),
            total_predicted_cost: served().map(|r| r.predicted_cost).sum(),
            avg_actual_e2e_ms: stats::mean(
                &served().map(|r| r.actual_e2e_ms).collect::<Vec<_>>(),
            ),
            avg_predicted_e2e_ms: stats::mean(
                &served().map(|r| r.predicted_e2e_ms).collect::<Vec<_>>(),
            ),
            edge_count: served().filter(|r| r.is_edge()).count(),
            cloud_count: served().filter(|r| !r.is_edge()).count(),
            warm_cold_mismatches: served().filter(|r| r.warm_cold_mismatch()).count(),
            cloud_actual_warm: served()
                .filter(|r| r.warm_actual == Some(true))
                .count(),
            cloud_actual_cold: served()
                .filter(|r| r.warm_actual == Some(false))
                .count(),
        }
    }

    /// Table III "Cost Prediction Error %": |total actual − total predicted|
    /// as a percentage of total actual.
    pub fn cost_prediction_error_pct(&self) -> f64 {
        stats::ape(self.total_actual_cost, self.total_predicted_cost)
    }

    /// Table IV "Latency Prediction Error %": APE of the average e2e latency.
    pub fn latency_prediction_error_pct(&self) -> f64 {
        stats::ape(self.avg_actual_e2e_ms, self.avg_predicted_e2e_ms)
    }
}

/// Deadline metrics for Table III.
pub fn deadline_violations(records: &[TaskRecord], deadline_ms: f64) -> (f64, f64) {
    let violations: Vec<f64> = records
        .iter()
        .filter(|r| r.actual_e2e_ms > deadline_ms)
        .map(|r| r.actual_e2e_ms - deadline_ms)
        .collect();
    let pct = violations.len() as f64 / records.len().max(1) as f64 * 100.0;
    (pct, stats::mean(&violations))
}

/// Cost-constraint metrics for Table IV: share of tasks whose *actual* cost
/// exceeded the cap applied at decision time, and % of total budget used.
pub fn budget_metrics(records: &[TaskRecord], cmax: f64) -> (f64, f64) {
    let n = records.len().max(1);
    let violated = records
        .iter()
        .filter(|r| r.actual_cost > r.allowed_cost + 1e-15)
        .count();
    let total_cost: f64 = records.iter().map(|r| r.actual_cost).sum();
    let budget = cmax * n as f64;
    (violated as f64 / n as f64 * 100.0, total_cost / budget * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(
        e2e_a: f64,
        e2e_p: f64,
        cost_a: f64,
        cost_p: f64,
        edge: bool,
        allowed: f64,
    ) -> TaskRecord {
        TaskRecord {
            id: 0,
            arrive_ms: 0.0,
            placement: if edge { Placement::Edge } else { Placement::Cloud(0) },
            predicted_e2e_ms: e2e_p,
            actual_e2e_ms: e2e_a,
            predicted_cost: cost_p,
            actual_cost: cost_a,
            allowed_cost: allowed,
            feasible_found: true,
            warm_predicted: if edge { None } else { Some(true) },
            warm_actual: if edge { None } else { Some(false) },
            edge_wait_ms: 0.0,
            rejected: false,
            failover_hops: 0,
            failover_routing_ms: 0.0,
            throttle_wait_ms: 0.0,
        }
    }

    #[test]
    fn summary_totals() {
        let rs = vec![
            rec(1000.0, 900.0, 2e-6, 1.5e-6, false, f64::INFINITY),
            rec(2000.0, 2100.0, 0.0, 0.0, true, f64::INFINITY),
        ];
        let s = Summary::from_records(&rs);
        assert_eq!(s.n, 2);
        assert_eq!(s.edge_count, 1);
        assert_eq!(s.cloud_count, 1);
        assert!((s.total_actual_cost - 2e-6).abs() < 1e-18);
        assert!((s.avg_actual_e2e_ms - 1500.0).abs() < 1e-9);
        assert_eq!(s.warm_cold_mismatches, 1);
    }

    #[test]
    fn cost_error_is_ape_of_totals() {
        let rs = vec![rec(1.0, 1.0, 10e-6, 9e-6, false, f64::INFINITY)];
        let s = Summary::from_records(&rs);
        assert!((s.cost_prediction_error_pct() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn deadline_metrics() {
        let rs = vec![
            rec(900.0, 0.0, 0.0, 0.0, true, f64::INFINITY),
            rec(1200.0, 0.0, 0.0, 0.0, true, f64::INFINITY),
            rec(1100.0, 0.0, 0.0, 0.0, true, f64::INFINITY),
        ];
        let (pct, avg) = deadline_violations(&rs, 1000.0);
        assert!((pct - 66.66666).abs() < 1e-3);
        assert!((avg - 150.0).abs() < 1e-9);
    }

    #[test]
    fn budget_metrics_count_allowed_cap() {
        let rs = vec![
            rec(1.0, 1.0, 5e-6, 5e-6, false, 4e-6), // actual over its cap
            rec(1.0, 1.0, 3e-6, 3e-6, false, 4e-6),
        ];
        let (viol, used) = budget_metrics(&rs, 4e-6);
        assert!((viol - 50.0).abs() < 1e-9);
        assert!((used - 100.0).abs() < 1e-9);
    }

    #[test]
    fn empty_records_safe() {
        let s = Summary::from_records(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.rejected_count, 0);
        let (pct, avg) = deadline_violations(&[], 100.0);
        assert_eq!((pct, avg), (0.0, 0.0));
    }

    #[test]
    fn rejected_tasks_counted_but_excluded_from_aggregates() {
        let mut rejected = rec(0.0, 0.0, 0.0, 2e-6, false, f64::INFINITY);
        rejected.rejected = true;
        rejected.warm_predicted = None;
        rejected.warm_actual = None;
        rejected.failover_hops = 2;
        let served = rec(1000.0, 900.0, 3e-6, 3e-6, false, f64::INFINITY);
        let s = Summary::from_records(&[rejected, served]);
        assert_eq!(s.n, 2);
        assert_eq!(s.rejected_count, 1);
        assert_eq!(s.failover_hops, 2);
        assert_eq!(s.cloud_count, 1, "rejected tasks never executed anywhere");
        assert_eq!(s.edge_count, 0);
        assert!((s.avg_actual_e2e_ms - 1000.0).abs() < 1e-9, "mean over served only");
        assert!((s.total_actual_cost - 3e-6).abs() < 1e-18);
        assert!(
            (s.total_predicted_cost - 3e-6).abs() < 1e-18,
            "a rejected task's decision-time prediction stays out of the totals"
        );
    }
}
