//! Live prototype (paper Sec. VI-B): the framework running on real threads
//! and wall-clock time rather than virtual simulation time.
//!
//! Topology (tokio is unavailable offline; std threads + channels):
//!  * the **ingest/decision thread** (this thread) releases inputs at the
//!    app's fixed rate, scores each through the Predictor — the XLA
//!    artifact on the hot path in production mode — runs the Decision
//!    Engine, and dispatches;
//!  * the **edge worker thread** drains a FIFO channel, sleeping the actual
//!    compute duration per task (the Greengrass long-lived function);
//!  * **cloud worker threads** are spawned per request (AWS Lambda scales
//!    out per invocation), sleeping upload/start/compute/store durations and
//!    sharing the ground-truth container pools behind a mutex.
//!
//! All durations are scaled by `time_scale` so a 150 s (virtual) run
//! finishes in seconds while preserving the concurrency structure; measured
//! wall-clock latencies are scaled back to virtual ms for reporting.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::config::{ExperimentSettings, Meta};
use crate::engine::DecisionEngine;
use crate::fleet::metrics::{latency_percentiles, LatencyPercentiles};
use crate::metrics::{Summary, TaskRecord};
use crate::platform::containers::StartKind;
use crate::platform::lambda::CloudPlatform;
use crate::platform::latency::GroundTruthSampler;
use crate::platform::pricing::aws_pricing;
use crate::predictor::{Placement, Predictor};
use crate::util::panic_message;
use crate::workload::build_workload;

/// Live-run parameters.
#[derive(Debug, Clone)]
pub struct LiveConfig {
    pub settings: ExperimentSettings,
    /// wall seconds per virtual second (0.05 → 20× faster than real time)
    pub time_scale: f64,
    /// ingest at a fixed rate (the paper's prototype) instead of Poisson
    pub fixed_rate: bool,
}

/// Outcome of one live run.
pub struct LiveOutcome {
    pub records: Vec<TaskRecord>,
    pub summary: Summary,
    /// actual e2e latency tail (virtual ms), via the fleet percentile helper
    pub latency: LatencyPercentiles,
    pub wall_seconds: f64,
}

struct EdgeJob {
    id: usize,
    comp_ms: f64,
    iotup_ms: f64,
    store_ms: f64,
    dispatched: Instant,
    base: PartialRecord,
}

struct CloudJob {
    id: usize,
    j: usize,
    upld_ms: f64,
    comp_ms: f64,
    start_w_ms: f64,
    start_c_ms: f64,
    store_ms: f64,
    tidl_ms: f64,
    dispatched: Instant,
    warm_predicted: bool,
    base: PartialRecord,
}

#[derive(Clone)]
struct PartialRecord {
    arrive_virtual_ms: f64,
    predicted_e2e_ms: f64,
    predicted_cost: f64,
    allowed_cost: f64,
    feasible_found: bool,
}

fn scaled_sleep(ms: f64, scale: f64) {
    if ms > 0.0 {
        std::thread::sleep(Duration::from_secs_f64(ms * scale / 1000.0));
    }
}

/// Run the live prototype once.
pub fn run(meta: &Meta, cfg: &LiveConfig) -> Result<LiveOutcome> {
    let app = meta.app(&cfg.settings.app).clone();
    let s = &cfg.settings;
    let n = s.n_inputs.unwrap_or(app.n_eval);
    let tasks = build_workload(meta, &s.app, n, s.replay, s.seed)?;
    let scale = cfg.time_scale;

    let mut predictor = Predictor::with_backend_kind(meta, &app, s.backend)?;
    let config_idxs: Vec<usize> = s
        .config_set
        .iter()
        .map(|&m| meta.config_index(m).expect("config must be one of the 19"))
        .collect();
    let mut engine = DecisionEngine::new(
        s.objective,
        config_idxs,
        s.deadline_ms.unwrap_or(app.deadline_ms),
        s.cmax.unwrap_or(app.cmax),
        s.alpha.unwrap_or(app.alpha),
    )
    .with_risk_factor(s.risk_factor);
    let mut gt = GroundTruthSampler::new(meta, &s.app, s.seed ^ 0x11FE);

    let records: Arc<Mutex<Vec<Option<TaskRecord>>>> = Arc::new(Mutex::new(vec![None; n]));
    let cloud: Arc<Mutex<CloudPlatform>> =
        Arc::new(Mutex::new(CloudPlatform::new(meta.memory_configs_mb.len())));

    // ---- edge worker -----------------------------------------------------
    let (edge_tx, edge_rx) = mpsc::channel::<EdgeJob>();
    // predicted drain time of the edge queue, in virtual ms since t0
    let edge_pred_busy: Arc<Mutex<f64>> = Arc::new(Mutex::new(0.0));
    let edge_records = Arc::clone(&records);
    let edge_handle = std::thread::spawn(move || {
        while let Ok(job) = edge_rx.recv() {
            scaled_sleep(job.comp_ms, scale); // FIFO: serialized compute
            // iotup + store are I/O: do not block the executor thread, but
            // the task's latency includes them.
            let e2e_virtual =
                job.dispatched.elapsed().as_secs_f64() * 1000.0 / scale + job.iotup_ms + job.store_ms;
            let rec = TaskRecord {
                id: job.id,
                arrive_ms: job.base.arrive_virtual_ms,
                placement: Placement::Edge,
                predicted_e2e_ms: job.base.predicted_e2e_ms,
                actual_e2e_ms: e2e_virtual,
                predicted_cost: job.base.predicted_cost,
                actual_cost: 0.0,
                allowed_cost: job.base.allowed_cost,
                feasible_found: job.base.feasible_found,
                warm_predicted: None,
                warm_actual: None,
                edge_wait_ms: 0.0,
            };
            edge_records.lock().unwrap()[job.id] = Some(rec);
        }
    });

    // ---- ingest / decision loop ------------------------------------------
    let t0 = Instant::now();
    let virtual_now = |t0: &Instant| t0.elapsed().as_secs_f64() * 1000.0 / scale;
    let mut cloud_handles = Vec::new();
    let gap_ms = 1000.0 / app.arrival_rate_per_s;

    for (i, task) in tasks.iter().enumerate() {
        // release at fixed rate (paper prototype) or replayed Poisson times
        let release_ms = if cfg.fixed_rate { i as f64 * gap_ms } else { task.arrive_ms };
        let behind = release_ms - virtual_now(&t0);
        if behind > 0.0 {
            scaled_sleep(behind, scale);
        }
        let now_v = virtual_now(&t0);
        let a = &task.actuals;

        // hot path: predictor (XLA executes here in production mode)
        let pred = predictor.predict(a.size, now_v)?;
        let edge_wait_pred = (*edge_pred_busy.lock().unwrap() - now_v).max(0.0);
        let decision = engine.decide(&pred, edge_wait_pred);
        predictor.update_cil(decision.placement, &pred, now_v);

        let base = PartialRecord {
            arrive_virtual_ms: now_v,
            predicted_e2e_ms: decision.predicted_e2e_ms,
            predicted_cost: decision.predicted_cost,
            allowed_cost: decision.allowed_cost,
            feasible_found: decision.feasible_found,
        };

        match decision.placement {
            Placement::Edge => {
                {
                    let mut b = edge_pred_busy.lock().unwrap();
                    *b = b.max(now_v) + pred.edge_comp_ms;
                }
                edge_tx
                    .send(EdgeJob {
                        id: task.id,
                        comp_ms: a.edge_comp,
                        iotup_ms: a.iotup,
                        store_ms: a.edge_store,
                        dispatched: Instant::now(),
                        base,
                    })
                    .map_err(|_| anyhow!("edge worker exited before the run finished"))?;
            }
            Placement::Cloud(j) => {
                let job = CloudJob {
                    id: task.id,
                    j,
                    upld_ms: a.upld,
                    comp_ms: a.comp[j],
                    start_w_ms: a.start_w,
                    start_c_ms: a.start_c,
                    store_ms: a.store,
                    tidl_ms: gt.sample_tidl(),
                    dispatched: Instant::now(),
                    warm_predicted: pred.cloud[j].warm,
                    base,
                };
                let cloud = Arc::clone(&cloud);
                let records = Arc::clone(&records);
                let mem = meta.memory_configs_mb[j];
                let t0c = t0;
                cloud_handles.push(std::thread::spawn(move || {
                    scaled_sleep(job.upld_ms, scale);
                    let trig_v = t0c.elapsed().as_secs_f64() * 1000.0 / scale;
                    let (kind, start_ms) = {
                        let mut c = cloud.lock().unwrap();
                        let warm = c.pool(job.j).peek_warm(trig_v);
                        let start = if warm { job.start_w_ms } else { job.start_c_ms };
                        let e = c.execute(
                            job.j, trig_v - job.upld_ms, job.upld_ms, job.comp_ms,
                            job.start_w_ms, job.start_c_ms, job.store_ms, job.tidl_ms,
                        );
                        (e.kind, start)
                    };
                    scaled_sleep(start_ms + job.comp_ms + job.store_ms, scale);
                    let e2e_virtual = job.dispatched.elapsed().as_secs_f64() * 1000.0 / scale;
                    let rec = TaskRecord {
                        id: job.id,
                        arrive_ms: job.base.arrive_virtual_ms,
                        placement: Placement::Cloud(job.j),
                        predicted_e2e_ms: job.base.predicted_e2e_ms,
                        actual_e2e_ms: e2e_virtual,
                        predicted_cost: job.base.predicted_cost,
                        actual_cost: aws_pricing().cost(job.comp_ms, mem),
                        allowed_cost: job.base.allowed_cost,
                        feasible_found: job.base.feasible_found,
                        warm_predicted: Some(job.warm_predicted),
                        warm_actual: Some(kind == StartKind::Warm),
                        edge_wait_ms: 0.0,
                    };
                    records.lock().unwrap()[job.id] = Some(rec);
                }));
            }
        }
    }

    drop(edge_tx);
    for h in cloud_handles {
        h.join()
            .map_err(|e| anyhow!("cloud worker panicked: {}", panic_message(&*e)))?;
    }
    edge_handle
        .join()
        .map_err(|e| anyhow!("edge worker panicked: {}", panic_message(&*e)))?;

    let records: Vec<TaskRecord> = Arc::try_unwrap(records)
        .map_err(|_| anyhow!("a worker still holds the record table after join"))?
        .into_inner()
        .map_err(|_| anyhow!("record table poisoned by a worker panic"))?
        .into_iter()
        .enumerate()
        .map(|(id, r)| r.ok_or_else(|| anyhow!("task {id} was never recorded")))
        .collect::<Result<_>>()?;
    let summary = Summary::from_records(&records);
    let e2e: Vec<f64> = records.iter().map(|r| r.actual_e2e_ms).collect();
    let latency = latency_percentiles(&e2e);
    Ok(LiveOutcome { records, summary, latency, wall_seconds: t0.elapsed().as_secs_f64() })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{default_artifact_dir, Objective, PredictorBackendKind};

    fn meta() -> Meta {
        Meta::load(&default_artifact_dir()).unwrap()
    }

    #[test]
    fn live_fd_latmin_small_run() {
        let meta = meta();
        let settings =
            ExperimentSettings::new("fd", Objective::LatencyMin, &[1536.0, 1664.0, 2048.0])
                .with_n_inputs(40)
                .with_backend(PredictorBackendKind::Native);
        let cfg = LiveConfig { settings, time_scale: 0.004, fixed_rate: true };
        let out = run(&meta, &cfg).unwrap();
        assert_eq!(out.records.len(), 40);
        assert!(out.summary.avg_actual_e2e_ms > 0.0);
        // tail summary comes from the shared fleet percentile helper
        assert!(out.latency.p50 > 0.0);
        assert!(out.latency.p50 <= out.latency.p95 && out.latency.p95 <= out.latency.p99);
        // live latency should be in the same ballpark as predicted
        let err = out.summary.latency_prediction_error_pct();
        assert!(err < 60.0, "latency prediction error {err}%");
        // all tasks recorded exactly once, ids intact
        let mut ids: Vec<usize> = out.records.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..40).collect::<Vec<_>>());
    }

    #[test]
    fn live_warm_cold_tracking() {
        let meta = meta();
        let settings =
            ExperimentSettings::new("stt", Objective::LatencyMin, &[1152.0, 1280.0, 1664.0])
                .with_n_inputs(12)
                .with_backend(PredictorBackendKind::Native);
        // STT arrives every 10 s; crank the scale so the test is fast
        let cfg = LiveConfig { settings, time_scale: 0.001, fixed_rate: true };
        let out = run(&meta, &cfg).unwrap();
        let cloud: Vec<_> = out.records.iter().filter(|r| !r.is_edge()).collect();
        if !cloud.is_empty() {
            // at least the very first cloud execution must be an actual cold
            assert!(cloud.iter().any(|r| r.warm_actual == Some(false)));
        }
    }
}
