//! Live prototype (paper Sec. VI-B): the framework running on real threads
//! and wall-clock time rather than virtual simulation time.
//!
//! Live mode is a **thin wall-clock dispatcher over the shared per-device
//! stepper**: every arrival goes through [`Device::ingest`] — the same
//! predict → decide → updateCIL → dispatch body `sim::run` and the fleet
//! drive — and the resulting [`Dispatch`] is mapped onto the thread
//! topology. No predict/decide/CIL logic of its own lives here, so the
//! sim/fleet/region scoring core (one Eqn.-1 body, router-backed CILs,
//! region-aware candidates) is exactly what the prototype validates.
//!
//! Topology (tokio is unavailable offline; std threads + channels):
//!  * the **ingest/decision thread** (this thread) releases inputs at their
//!    scheduled times — fixed rate (the paper's prototype) or the replayed
//!    Poisson stream — and steps the [`Device`];
//!  * the **edge worker thread** drains a FIFO channel, sleeping the actual
//!    compute duration per task (the Greengrass long-lived function);
//!  * **cloud worker threads** are spawned per request (AWS Lambda scales
//!    out per invocation): they sleep the upload leg, apply the request to
//!    the ground-truth container pools behind a mutex via
//!    [`device::execute_cloud`], assemble the record with
//!    [`device::complete_cloud`], and sleep out start/compute/store.
//!
//! Task records carry the platform's virtual-time math (identical to the
//! simulator's, which is what the live-vs-sim parity suite pins); the
//! measured wall-clock tail is reported separately as `wall_latency`. All
//! sleeps are scaled by `time_scale` so a 150 s (virtual) run finishes in
//! seconds while preserving the concurrency structure.
//!
//! With `FeedbackMode::Observe`, each cloud worker ships the realized
//! start kind back over the completion channel and the ingest thread folds
//! it into the device's working CIL before the next decision — the
//! closed-loop feedback arrives exactly when the response lands, like a
//! real client would see it.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::config::{ExperimentSettings, FeedbackMode, Meta};
use crate::fleet::device::{self, CloudObservation, Device, DeviceProfile, Dispatch};
use crate::fleet::scenario::TIDL_SALT;
use crate::metrics::TaskRecord;
use crate::obs::event::{EventMeta, Stages, TaskEvent};
use crate::obs::profile::Stopwatch;
use crate::obs::sink::Recorder;
use crate::platform::containers::StartKind;
use crate::platform::lambda::CloudPlatform;
use crate::runtime::{latency_percentiles, LatencyPercentiles, RunOutcome};
use crate::util::panic_message;
use crate::workload::build_workload;

/// Live-run parameters.
#[derive(Debug, Clone)]
pub struct LiveConfig {
    pub settings: ExperimentSettings,
    /// wall seconds per virtual second (0.05 → 20× faster than real time)
    pub time_scale: f64,
    /// ingest at a fixed rate (the paper's prototype) instead of Poisson
    pub fixed_rate: bool,
}

/// Outcome of one live run. Derefs to the unified [`RunOutcome`] core
/// (records, summary, latency percentiles — the platform's virtual-time
/// view, shared with `sim::run` and the fleet).
pub struct LiveOutcome {
    pub run: RunOutcome,
    pub wall_seconds: f64,
    /// measured wall-clock e2e tail, scaled back to virtual ms — what the
    /// threads actually experienced, scheduling jitter included; `None`
    /// for an empty run
    pub wall_latency: Option<LatencyPercentiles>,
    /// mean measured wall-clock e2e (virtual ms)
    pub wall_avg_e2e_ms: f64,
}

impl LiveOutcome {
    /// The prototype's headline metric (paper Sec. VI-B, Table V): latency
    /// prediction error against the **measured** wall-clock average. The
    /// records' `summary.latency_prediction_error_pct()` is the
    /// virtual-time (simulator-identical) view; this one keeps real
    /// thread scheduling and contention in the denominator.
    pub fn wall_latency_prediction_error_pct(&self) -> f64 {
        crate::util::stats::ape(self.wall_avg_e2e_ms, self.summary.avg_predicted_e2e_ms)
    }
}

impl std::ops::Deref for LiveOutcome {
    type Target = RunOutcome;

    fn deref(&self) -> &RunOutcome {
        &self.run
    }
}

/// One finished edge execution queued behind the edge worker's FIFO.
struct EdgeJob {
    /// stepper-produced record (virtual-time math, real queue wait)
    record: TaskRecord,
    /// actual compute the worker serializes (scaled sleep)
    comp_ms: f64,
    /// iotup + store: I/O after compute; part of latency, not of the FIFO
    tail_ms: f64,
    dispatched: Stopwatch,
}

/// What a worker reports back to the ingest thread.
struct Completion {
    record: TaskRecord,
    /// measured wall-clock e2e, scaled back to virtual ms
    measured_ms: f64,
    /// realized cloud outcome (feedback mode only)
    obs: Option<CloudObservation>,
    /// lifecycle events assembled worker-side (recording mode only; the
    /// ingest thread's `Recorder` sorts them into canonical order)
    events: Vec<TaskEvent>,
}

fn scaled_sleep(ms: f64, scale: f64) {
    if ms > 0.0 {
        std::thread::sleep(Duration::from_secs_f64(ms * scale / 1000.0));
    }
}

/// Fold one worker completion into the run state: apply the realized cloud
/// outcome to the device's working CIL (feedback mode), then file the
/// record and the measured wall latency under the task id.
fn collect(
    c: Completion,
    dev: &mut Device<'_>,
    slots: &mut [Option<TaskRecord>],
    measured: &mut [Option<f64>],
    recorder: Option<&mut Recorder>,
) {
    if let Some(r) = recorder {
        r.extend(c.events);
    }
    // observations exist only under FeedbackMode::Observe — with feedback
    // off none is ever constructed, same as the sim and fleet paths
    if let Some(obs) = &c.obs {
        dev.observe_cloud(obs);
    }
    measured[c.record.id] = Some(c.measured_ms);
    slots[c.record.id] = Some(c.record);
}

/// Run the live prototype once.
pub fn run(meta: &Meta, cfg: &LiveConfig) -> Result<LiveOutcome> {
    run_inner(meta, cfg, None)
}

/// [`run`] with the typed event stream recorded: the devices emit
/// arrival/decision/completion events inside the shared stepper and the
/// cloud workers ship container-start/completion/observation events back
/// with their results; the returned stream is in canonical order.
pub fn run_recorded(meta: &Meta, cfg: &LiveConfig) -> Result<(LiveOutcome, Vec<TaskEvent>)> {
    let mut recorder = Recorder::new();
    let out = run_inner(meta, cfg, Some(&mut recorder))?;
    Ok((out, recorder.into_events()))
}

fn run_inner(
    meta: &Meta,
    cfg: &LiveConfig,
    mut recorder: Option<&mut Recorder>,
) -> Result<LiveOutcome> {
    let app = meta.app(&cfg.settings.app).clone();
    let s = &cfg.settings;
    let n = s.n_inputs.unwrap_or(app.n_eval);
    let tasks = build_workload(meta, &s.app, n, s.replay, s.seed)?;
    let scale = cfg.time_scale;
    let feedback = s.feedback == FeedbackMode::Observe;
    let recording = recorder.is_some();

    // the same device construction as `sim::run` — bad configuration sets
    // surface as errors here instead of panicking mid-run
    let profile = DeviceProfile::uniform(0, &s.app, s.seed ^ TIDL_SALT);
    let mut dev = Device::new(meta, s, profile)?;
    dev.recording = recording;
    if let Some(rec) = recorder.as_deref_mut() {
        rec.push(TaskEvent::ScenarioPhase { t_ms: 0.0, label: format!("live:{}", s.app) });
    }
    let cloud: Arc<Mutex<CloudPlatform>> =
        Arc::new(Mutex::new(CloudPlatform::new(meta.memory_configs_mb.len())));

    let (done_tx, done_rx) = mpsc::channel::<Completion>();

    // ---- edge worker -----------------------------------------------------
    let (edge_tx, edge_rx) = mpsc::channel::<EdgeJob>();
    let edge_done = done_tx.clone();
    let edge_handle = std::thread::spawn(move || {
        while let Ok(job) = edge_rx.recv() {
            scaled_sleep(job.comp_ms, scale); // FIFO: serialized compute
            let measured_ms =
                job.dispatched.elapsed_s() * 1000.0 / scale + job.tail_ms;
            if edge_done
                .send(Completion {
                    record: job.record,
                    measured_ms,
                    obs: None,
                    events: Vec::new(),
                })
                .is_err()
            {
                return; // ingest thread gone
            }
        }
    });

    // ---- ingest / decision loop ------------------------------------------
    let t0 = Stopwatch::start();
    let virtual_now = |t0: &Stopwatch| t0.elapsed_s() * 1000.0 / scale;
    let mut cloud_handles = Vec::new();
    let gap_ms = 1000.0 / app.arrival_rate_per_s;
    let mut slots: Vec<Option<TaskRecord>> = vec![None; n];
    let mut measured: Vec<Option<f64>> = vec![None; n];

    for (i, task) in tasks.iter().enumerate() {
        // release at fixed rate (paper prototype) or replayed Poisson times
        let release_ms = if cfg.fixed_rate { i as f64 * gap_ms } else { task.arrive_ms };
        let behind = release_ms - virtual_now(&t0);
        if behind > 0.0 {
            scaled_sleep(behind, scale);
        }
        // fold in whatever the workers finished while we slept — with
        // feedback on, realized warm/cold outcomes correct the working CIL
        // before this decision
        while let Ok(c) = done_rx.try_recv() {
            collect(c, &mut dev, &mut slots, &mut measured, recorder.as_deref_mut());
        }

        // the shared stepper: predict → decide → updateCIL → dispatch
        match dev.ingest(task, release_ms)? {
            Dispatch::Edge(e) => {
                let a = &task.actuals;
                edge_tx
                    .send(EdgeJob {
                        record: e.record,
                        comp_ms: a.edge_comp,
                        tail_ms: a.iotup + a.edge_store,
                        dispatched: Stopwatch::start(),
                    })
                    .map_err(|_| anyhow!("edge worker exited before the run finished"))?;
            }
            Dispatch::Cloud(req) => {
                let cloud = Arc::clone(&cloud);
                let done = done_tx.clone();
                let dispatched = Stopwatch::start();
                let app_name = s.app.clone();
                cloud_handles.push(std::thread::spawn(move || {
                    scaled_sleep(req.upld_ms + req.routing_ms, scale);
                    // the pools decide warm vs cold at (virtual) trigger
                    // time — the same ground truth the simulator applies
                    let (exec, record) = {
                        // a worker panicking while holding the pool lock is
                        // already fatal to the run (join surfaces it); keep
                        // serving rather than compounding with a poison panic
                        let mut pools =
                            cloud.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
                        let exec = device::execute_cloud(&req, &mut pools);
                        (exec, device::complete_cloud(&req, &exec))
                    };
                    let obs = feedback.then(|| CloudObservation::from_execution(&req, &exec));
                    let events = if recording {
                        let at = |t: f64| {
                            EventMeta::new(t, req.device_id, &app_name, req.seq, req.task_id)
                        };
                        let mut evs = vec![
                            TaskEvent::ContainerStart {
                                meta: at(exec.triggered_at),
                                region: req.region,
                                mem_mb: req.mem_mb,
                                warm: exec.kind == StartKind::Warm,
                                start_ms: exec.start_ms,
                            },
                            TaskEvent::Completion {
                                meta: at(exec.stored_at),
                                edge: false,
                                region: Some(req.region),
                                warm: record.warm_actual,
                                e2e_ms: record.actual_e2e_ms,
                                cost: record.actual_cost,
                                stages: Stages {
                                    upld: req.upld_ms,
                                    routing: req.routing_ms,
                                    start: exec.start_ms,
                                    comp: req.comp_ms,
                                    store: req.store_ms,
                                    ..Default::default()
                                },
                            },
                        ];
                        if let Some(o) = &obs {
                            evs.push(TaskEvent::Observation {
                                meta: at(exec.stored_at),
                                region: req.region,
                                warm: o.warm,
                            });
                        }
                        evs
                    } else {
                        Vec::new()
                    };
                    scaled_sleep(exec.start_ms + req.comp_ms + req.store_ms, scale);
                    let measured_ms = dispatched.elapsed_s() * 1000.0 / scale;
                    let _ = done.send(Completion { record, measured_ms, obs, events });
                }));
            }
        }
    }

    drop(edge_tx);
    for h in cloud_handles {
        h.join()
            .map_err(|e| anyhow!("cloud worker panicked: {}", panic_message(&*e)))?;
    }
    edge_handle
        .join()
        .map_err(|e| anyhow!("edge worker panicked: {}", panic_message(&*e)))?;
    drop(done_tx);
    for c in done_rx {
        collect(c, &mut dev, &mut slots, &mut measured, recorder.as_deref_mut());
    }
    if let Some(rec) = recorder.as_deref_mut() {
        // arrival/decision/edge-completion events accumulated in the device
        rec.extend(std::mem::take(&mut dev.events));
    }

    let wall: Vec<f64> = measured.iter().copied().flatten().collect();
    Ok(LiveOutcome {
        run: RunOutcome::from_slots(slots)?,
        wall_seconds: t0.elapsed_s(),
        wall_latency: latency_percentiles(&wall),
        wall_avg_e2e_ms: crate::util::stats::mean(&wall),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{default_artifact_dir, Objective, PredictorBackendKind};

    fn meta() -> Meta {
        Meta::load(&default_artifact_dir()).unwrap()
    }

    #[test]
    fn live_fd_latmin_small_run() {
        let meta = meta();
        let settings =
            ExperimentSettings::new("fd", Objective::LatencyMin, &[1536.0, 1664.0, 2048.0])
                .with_n_inputs(40)
                .with_backend(PredictorBackendKind::Native);
        let cfg = LiveConfig { settings, time_scale: 0.004, fixed_rate: true };
        let out = run(&meta, &cfg).unwrap();
        assert_eq!(out.records.len(), 40);
        assert!(out.summary.avg_actual_e2e_ms > 0.0);
        // tail summaries come from the shared run-outcome core
        let lat = out.latency.expect("non-empty live run has percentiles");
        assert!(lat.p50 > 0.0);
        assert!(lat.p50 <= lat.p95 && lat.p95 <= lat.p99);
        assert!(out.wall_latency.expect("measured tail present").p50 > 0.0);
        assert!(out.wall_avg_e2e_ms > 0.0);
        // live latency should be in the same ballpark as predicted — both
        // the virtual-time view and the measured wall-clock one
        let err = out.summary.latency_prediction_error_pct();
        assert!(err < 60.0, "latency prediction error {err}%");
        let wall_err = out.wall_latency_prediction_error_pct();
        assert!(wall_err < 100.0, "measured prediction error {wall_err}%");
        // all tasks recorded exactly once, ids intact
        let mut ids: Vec<usize> = out.records.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..40).collect::<Vec<_>>());
    }

    #[test]
    fn live_warm_cold_tracking() {
        let meta = meta();
        let settings =
            ExperimentSettings::new("stt", Objective::LatencyMin, &[1152.0, 1280.0, 1664.0])
                .with_n_inputs(12)
                .with_backend(PredictorBackendKind::Native);
        // STT arrives every 10 s; crank the scale so the test is fast
        let cfg = LiveConfig { settings, time_scale: 0.001, fixed_rate: true };
        let out = run(&meta, &cfg).unwrap();
        let cloud: Vec<_> = out.records.iter().filter(|r| !r.is_edge()).collect();
        if !cloud.is_empty() {
            // at least the very first cloud execution must be an actual cold
            assert!(cloud.iter().any(|r| r.warm_actual == Some(false)));
        }
    }

    #[test]
    fn live_recording_covers_every_task_in_canonical_order() {
        let meta = meta();
        let settings =
            ExperimentSettings::new("fd", Objective::LatencyMin, &[1536.0, 1664.0, 2048.0])
                .with_n_inputs(20)
                .with_backend(PredictorBackendKind::Native);
        let cfg = LiveConfig { settings, time_scale: 0.004, fixed_rate: true };
        let (out, events) = run_recorded(&meta, &cfg).unwrap();
        assert_eq!(out.records.len(), 20);
        let count = |k: &str| events.iter().filter(|e| e.kind() == k).count();
        assert_eq!(count("arrival"), 20, "one arrival event per task");
        assert_eq!(count("decision"), 20);
        assert_eq!(count("completion"), 20, "one completion event per task");
        for w in events.windows(2) {
            assert_ne!(
                TaskEvent::canonical_cmp(&w[0], &w[1]),
                std::cmp::Ordering::Greater,
                "recorded stream must be canonically ordered"
            );
        }
    }

    // the bad-config error twin of the simulator's pin lives in
    // rust/tests/live.rs (it also checks the error message)
}
