//! Edge substrate: AWS Greengrass long-lived lambda with a FIFO task queue
//! (paper Sec. II-A2 / III-A "Executor").
//!
//! The edge device runs a single long-lived function; tasks placed at the
//! edge queue up and execute one at a time. End-to-end latency for an edge
//! task is queue wait + comp_e + iotup + store (Eqn. 2 plus queueing).

/// The edge Executor: FIFO queue + busy-until bookkeeping on virtual time.
#[derive(Debug, Default)]
pub struct EdgeExecutor {
    /// time at which the currently queued/executing work drains
    busy_until: f64,
    /// predicted drain time (same shape, but accumulated from predictions)
    predicted_busy_until: f64,
    queue_len: usize,
    pub executed: u64,
}

impl EdgeExecutor {
    pub fn new() -> Self {
        Self::default()
    }

    /// Predicted additional wait before a task submitted at `now` would
    /// begin computing (based on predicted durations of queued work).
    pub fn predicted_wait(&self, now: f64) -> f64 {
        (self.predicted_busy_until - now).max(0.0)
    }

    /// Actual wait a task submitted at `now` will incur.
    pub fn actual_wait(&self, now: f64) -> f64 {
        (self.busy_until - now).max(0.0)
    }

    pub fn queue_len(&self) -> usize {
        self.queue_len
    }

    /// Submit a task at `now`; returns (wait_ms, comp_start, comp_end).
    /// The FIFO discipline serializes compute; iotup/store happen after
    /// compute and do not occupy the executor (they are I/O).
    pub fn submit(&mut self, now: f64, comp_ms: f64, predicted_comp_ms: f64) -> (f64, f64, f64) {
        let wait = self.actual_wait(now);
        let start = now + wait;
        let end = start + comp_ms;
        self.busy_until = end;
        self.predicted_busy_until = self.predicted_busy_until.max(now) + predicted_comp_ms;
        self.queue_len += 1;
        self.executed += 1;
        (wait, start, end)
    }

    /// Mark one task drained (bookkeeping for queue length metrics).
    pub fn drain_one(&mut self) {
        self.queue_len = self.queue_len.saturating_sub(1);
    }

    /// Is the executor idle at `now`?
    pub fn is_idle(&self, now: f64) -> bool {
        now >= self.busy_until
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idle_executor_starts_immediately() {
        let mut e = EdgeExecutor::new();
        let (wait, start, end) = e.submit(100.0, 50.0, 55.0);
        assert_eq!(wait, 0.0);
        assert_eq!(start, 100.0);
        assert_eq!(end, 150.0);
    }

    #[test]
    fn fifo_serializes_compute() {
        let mut e = EdgeExecutor::new();
        e.submit(0.0, 100.0, 100.0);
        let (wait, start, end) = e.submit(10.0, 50.0, 50.0);
        assert_eq!(wait, 90.0);
        assert_eq!(start, 100.0);
        assert_eq!(end, 150.0);
        // third task queues behind both
        let (w3, s3, _) = e.submit(20.0, 10.0, 10.0);
        assert_eq!(w3, 130.0);
        assert_eq!(s3, 150.0);
    }

    #[test]
    fn predicted_wait_uses_predictions_not_actuals() {
        let mut e = EdgeExecutor::new();
        e.submit(0.0, 100.0, 80.0); // actual 100, predicted 80
        assert_eq!(e.predicted_wait(0.0), 80.0);
        assert_eq!(e.actual_wait(0.0), 100.0);
    }

    #[test]
    fn queue_drains_over_time() {
        let mut e = EdgeExecutor::new();
        e.submit(0.0, 100.0, 100.0);
        assert!(!e.is_idle(50.0));
        assert!(e.is_idle(100.0));
        let (wait, _, _) = e.submit(200.0, 10.0, 10.0);
        assert_eq!(wait, 0.0);
    }

    #[test]
    fn queue_len_bookkeeping() {
        let mut e = EdgeExecutor::new();
        e.submit(0.0, 10.0, 10.0);
        e.submit(0.0, 10.0, 10.0);
        assert_eq!(e.queue_len(), 2);
        e.drain_one();
        assert_eq!(e.queue_len(), 1);
        e.drain_one();
        e.drain_one(); // saturates at 0
        assert_eq!(e.queue_len(), 0);
    }

    #[test]
    fn blowup_under_overload() {
        // FD-like: service 8 s, arrivals every 250 ms — queue wait explodes,
        // reproducing the paper's 2404 s edge-only average.
        let mut e = EdgeExecutor::new();
        let mut waits = Vec::new();
        for i in 0..600 {
            let now = i as f64 * 250.0;
            let (w, _, _) = e.submit(now, 8000.0, 8000.0);
            waits.push(w);
        }
        let avg = waits.iter().sum::<f64>() / waits.len() as f64;
        assert!(avg > 1_000_000.0, "avg wait {avg} ms should exceed 1000 s");
    }
}
