//! AWS Lambda pricing model (paper Sec. II-A):
//! billed duration = execution time rounded **up** to the next 100 ms;
//! price proportional to container memory at $1.667e-6 per GB-s, plus a
//! flat $0.20 per 1M requests. Edge (Greengrass) executions cost $0 —
//! the yearly device fee amortizes to zero per task.

use crate::config::Pricing;

impl Pricing {
    /// Billed duration in seconds for an execution time in ms.
    pub fn billed_seconds(&self, comp_ms: f64) -> f64 {
        (comp_ms.max(1.0) / self.bill_quantum_ms).ceil() * (self.bill_quantum_ms / 1e3)
    }

    /// Dollar cost of one cloud function execution.
    pub fn cost(&self, comp_ms: f64, mem_mb: f64) -> f64 {
        self.price_per_gb_s * (mem_mb / 1024.0) * self.billed_seconds(comp_ms) + self.request_fee
    }

    /// Edge executions are free under the amortized Greengrass model.
    pub fn edge_cost(&self) -> f64 {
        0.0
    }
}

/// The constants used throughout the paper (and baked into artifacts).
pub fn aws_pricing() -> Pricing {
    Pricing {
        price_per_gb_s: 1.667e-6,
        bill_quantum_ms: 100.0,
        request_fee: 0.20 / 1e6,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_quantization_example() {
        // "98 ms compute time would be rounded to 100ms, whereas a 101ms
        //  compute time will be rounded to 200ms"
        let p = aws_pricing();
        assert!((p.billed_seconds(98.0) - 0.1).abs() < 1e-12);
        assert!((p.billed_seconds(100.0) - 0.1).abs() < 1e-12);
        assert!((p.billed_seconds(101.0) - 0.2).abs() < 1e-12);
    }

    #[test]
    fn cost_scales_with_memory() {
        let p = aws_pricing();
        let t = 1000.0;
        let c1 = p.cost(t, 1024.0);
        let c2 = p.cost(t, 2048.0);
        assert!((c2 - p.request_fee - 2.0 * (c1 - p.request_fee)).abs() < 1e-15);
    }

    #[test]
    fn gb_second_price_exact() {
        let p = aws_pricing();
        // 1 GB container for exactly 1 s
        let c = p.cost(1000.0, 1024.0);
        assert!((c - (1.667e-6 + 0.2e-6)).abs() < 1e-15);
    }

    #[test]
    fn cost_monotone_in_time() {
        let p = aws_pricing();
        let mut prev = 0.0;
        for ms in [1.0, 99.0, 100.0, 150.0, 1000.0, 10_000.0] {
            let c = p.cost(ms, 1536.0);
            assert!(c >= prev);
            prev = c;
        }
    }

    #[test]
    fn edge_is_free() {
        assert_eq!(aws_pricing().edge_cost(), 0.0);
    }
}
