//! The AWS substrate simulator: everything the paper's framework runs
//! against — cloud container pools with warm/cold dynamics (`lambda`,
//! `containers`), the edge long-lived executor (`greengrass`), ground-truth
//! latency distributions (`latency`) and the AWS billing model (`pricing`).

pub mod admission;
pub mod containers;
pub mod greengrass;
pub mod lambda;
pub mod latency;
pub mod pricing;
