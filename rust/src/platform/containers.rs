//! Ground-truth container pool: what AWS actually does, as opposed to what
//! the Predictor's CIL *believes* it does.
//!
//! Per cloud configuration λ_m the platform keeps a set of containers. When a
//! function invocation arrives (after upload), an idle live container is
//! reused — AWS empirically assigns the **most recently used** one (paper
//! Sec. V-A) — producing a warm start; otherwise a new container is created
//! (cold start). A container is reclaimed once it has sat idle for its
//! sampled lifetime T_idl (~27 min, Wang et al.).

/// One live container in the ground-truth pool.
#[derive(Debug, Clone)]
pub struct Container {
    pub id: u64,
    /// busy executing a function until this time (ms); f64::NEG_INFINITY if never used
    pub busy_until: f64,
    /// completion time of the most recent function
    pub last_completion: f64,
    /// sampled idle lifetime; the container dies at last_completion + tidl
    pub tidl: f64,
}

impl Container {
    pub fn expires_at(&self) -> f64 {
        self.last_completion + self.tidl
    }

    pub fn is_idle(&self, now: f64) -> bool {
        now >= self.busy_until
    }

    pub fn is_live(&self, now: f64) -> bool {
        // busy containers never expire mid-execution
        now < self.busy_until || now <= self.expires_at()
    }
}

/// Outcome of an invocation against one configuration's pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StartKind {
    Warm,
    Cold,
}

/// Container pool for a single λ_m configuration.
#[derive(Debug, Default)]
pub struct ConfigPool {
    containers: Vec<Container>,
    next_id: u64,
    pub warm_count: u64,
    pub cold_count: u64,
}

impl ConfigPool {
    pub fn new() -> Self {
        Self::default()
    }

    /// Drop containers whose idle lifetime has elapsed by `now`.
    pub fn reap(&mut self, now: f64) {
        self.containers.retain(|c| c.is_live(now));
    }

    /// Would an invocation at `now` be warm?
    pub fn peek_warm(&self, now: f64) -> bool {
        self.containers
            .iter()
            .any(|c| c.is_idle(now) && c.is_live(now))
    }

    /// Invoke a function at time `now` running for `busy_ms` (start + comp).
    /// Returns (kind, container id). `tidl` is used only for a new container.
    pub fn invoke(&mut self, now: f64, busy_ms: f64, tidl: f64) -> (StartKind, u64) {
        self.reap(now);
        // most-recently-used idle container
        let candidate = self
            .containers
            .iter_mut()
            .filter(|c| c.is_idle(now))
            .max_by(|a, b| a.last_completion.total_cmp(&b.last_completion));
        if let Some(c) = candidate {
            c.busy_until = now + busy_ms;
            c.last_completion = now + busy_ms;
            self.warm_count += 1;
            return (StartKind::Warm, c.id);
        }
        let id = self.next_id;
        self.next_id += 1;
        self.containers.push(Container {
            id,
            busy_until: now + busy_ms,
            last_completion: now + busy_ms,
            tidl,
        });
        self.cold_count += 1;
        (StartKind::Cold, id)
    }

    pub fn live_count(&self, now: f64) -> usize {
        self.containers.iter().filter(|c| c.is_live(now)).count()
    }

    pub fn idle_count(&self, now: f64) -> usize {
        self.containers
            .iter()
            .filter(|c| c.is_idle(now) && c.is_live(now))
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_invocation_is_cold() {
        let mut p = ConfigPool::new();
        let (k, _) = p.invoke(0.0, 1000.0, 100_000.0);
        assert_eq!(k, StartKind::Cold);
        assert_eq!(p.cold_count, 1);
    }

    #[test]
    fn reuse_after_completion_is_warm() {
        let mut p = ConfigPool::new();
        p.invoke(0.0, 1000.0, 100_000.0);
        let (k, _) = p.invoke(1500.0, 500.0, 100_000.0);
        assert_eq!(k, StartKind::Warm);
        assert_eq!(p.warm_count, 1);
        assert_eq!(p.live_count(1500.0), 1);
    }

    #[test]
    fn busy_container_forces_cold() {
        let mut p = ConfigPool::new();
        p.invoke(0.0, 10_000.0, 100_000.0);
        let (k, _) = p.invoke(5000.0, 500.0, 100_000.0); // first is still busy
        assert_eq!(k, StartKind::Cold);
        assert_eq!(p.live_count(5000.0), 2);
    }

    #[test]
    fn container_expires_after_idle_lifetime() {
        let mut p = ConfigPool::new();
        p.invoke(0.0, 1000.0, 60_000.0); // completes at 1000, dies at 61_000
        assert!(p.peek_warm(60_000.0));
        assert!(!p.peek_warm(61_001.0));
        let (k, _) = p.invoke(61_001.0, 500.0, 60_000.0);
        assert_eq!(k, StartKind::Cold);
    }

    #[test]
    fn mru_container_selected() {
        let mut p = ConfigPool::new();
        // two containers completing at different times
        let (_, a) = p.invoke(0.0, 1000.0, 1e7);   // completes 1000
        let (_, b) = p.invoke(500.0, 1000.0, 1e7); // completes 1500 (MRU)
        assert_ne!(a, b);
        let (k, id) = p.invoke(2000.0, 500.0, 1e7);
        assert_eq!(k, StartKind::Warm);
        assert_eq!(id, b, "most recently used container must be reused");
    }

    #[test]
    fn reuse_extends_lifetime() {
        let mut p = ConfigPool::new();
        p.invoke(0.0, 1000.0, 60_000.0);
        // reuse at 50_000 pushes expiry to 50_500 + 60_000
        p.invoke(50_000.0, 500.0, 999.0);
        assert!(p.peek_warm(100_000.0));
    }

    #[test]
    fn counts_track_kinds() {
        let mut p = ConfigPool::new();
        p.invoke(0.0, 100.0, 1e6);
        p.invoke(200.0, 100.0, 1e6);
        p.invoke(250.0, 100.0, 1e6); // both busy? no: first idle at 200... second busy
        assert_eq!(p.warm_count + p.cold_count, 3);
    }
}
