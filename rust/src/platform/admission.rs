//! Per-region admission control: concurrency caps, rate limits, and outage
//! windows, applied at the coordinator in canonical request order.
//!
//! The paper assumes the chosen Lambda region always admits the request; at
//! fleet scale that assumption breaks first (LaSS-style overload, correlated
//! site failures). [`AdmissionControl`] is the ground-truth gate one
//! [`RegionRuntime`](crate::region::RegionRuntime) applies before its pools
//! are touched:
//!
//!  * `max_concurrent` — at most N functions executing at once across the
//!    region's pools (AWS account concurrency limit);
//!  * `max_rps` — at most R admissions per 1-second sliding window
//!    (API-gateway style throttling);
//!  * outage windows — scheduled blackouts during which nothing is admitted
//!    (correlated-outage scenarios), with recovery at the window end.
//!
//! [`AdmissionControl::admit`] is *decision-only*: it garbage-collects
//! expired state but commits nothing, so a caller may defer an admitted
//! request past an epoch horizon and re-ask later with an identical answer.
//! The caller commits exactly one of [`commit`](AdmissionControl::commit) /
//! [`reject`](AdmissionControl::reject) per final outcome, which is what
//! keeps the admission stream a pure function of the canonically-ordered
//! request sequence — independent of shard count and epoch length.

use std::collections::VecDeque;

use crate::config::{RegionSettings, ThrottlePolicy};

/// Length of the rate-limit sliding window (ms).
const RPS_WINDOW_MS: f64 = 1_000.0;

/// The gate's verdict for one request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Admission {
    /// admissible at `at_ms` (== the asked trigger when capacity is free
    /// now; later under `ThrottlePolicy::Queue` when a slot must free up)
    Admit { at_ms: f64 },
    /// denied: over capacity / rate / in an outage, and the throttle policy
    /// does not allow waiting (long enough)
    Reject,
}

/// Runtime admission state for one region.
pub struct AdmissionControl {
    max_concurrent: Option<usize>,
    max_rps: Option<f64>,
    throttle: ThrottlePolicy,
    /// blackout windows [start, end), sorted by start
    outages: Vec<(f64, f64)>,
    /// busy-until times of currently executing functions (capacity only)
    inflight: Vec<f64>,
    /// admission times inside the current rate window (rate limit only)
    window: VecDeque<f64>,
    /// requests ultimately admitted here
    pub admitted: u64,
    /// admission attempts denied here (failover retries count per region)
    pub rejected: u64,
    /// admitted requests that had to wait for a slot
    pub queued: u64,
    /// total slot wait accumulated by queued admissions (ms)
    pub queued_wait_ms: f64,
}

impl AdmissionControl {
    /// Build the gate for one region from its settings plus the topology's
    /// throttle policy and this region's outage windows.
    pub fn new(
        spec: &RegionSettings,
        throttle: ThrottlePolicy,
        mut outages: Vec<(f64, f64)>,
    ) -> Self {
        outages.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.total_cmp(&b.1)));
        AdmissionControl {
            max_concurrent: spec.max_concurrent,
            max_rps: spec.max_rps,
            throttle,
            outages,
            inflight: Vec::new(),
            window: VecDeque::new(),
            admitted: 0,
            rejected: 0,
            queued: 0,
            queued_wait_ms: 0.0,
        }
    }

    /// No limits configured: every request admits at its own trigger and
    /// the gate never mutates state beyond the admitted counter.
    pub fn unlimited(&self) -> bool {
        self.max_concurrent.is_none() && self.max_rps.is_none() && self.outages.is_empty()
    }

    /// Drop state that is dead at `now_ms` — the time of the *asked*
    /// trigger, never a look-ahead time. Admission attempts arrive in
    /// non-decreasing trigger order (the coordinator's canonical merge),
    /// so anything dead now stays dead for every later ask; collecting at
    /// any later candidate time would destroy entries that still constrain
    /// requests between now and then.
    fn gc(&mut self, now_ms: f64) {
        if self.max_concurrent.is_some() {
            self.inflight.retain(|&busy_until| busy_until > now_ms);
        }
        if self.max_rps.is_some() {
            while self.window.front().is_some_and(|&a| a <= now_ms - RPS_WINDOW_MS) {
                self.window.pop_front();
            }
        }
    }

    /// Earliest time ≥ `t` outside every outage window.
    fn after_outage(&self, mut t: f64) -> f64 {
        for &(start, end) in &self.outages {
            if t >= start && t < end {
                t = end;
            }
        }
        t
    }

    /// Earliest time ≥ `t` with a free concurrency slot (non-destructive:
    /// the fixpoint loop probes future times without touching state).
    fn after_capacity(&self, t: f64) -> f64 {
        let Some(cap) = self.max_concurrent else { return t };
        if cap == 0 {
            return f64::INFINITY;
        }
        let mut live: Vec<f64> =
            self.inflight.iter().copied().filter(|&busy_until| busy_until > t).collect();
        if live.len() < cap {
            return t;
        }
        // a slot frees once all but cap−1 of the live executions finish
        live.sort_by(f64::total_cmp);
        live[live.len() - cap]
    }

    /// Earliest time ≥ `t` with room in the rate window (non-destructive).
    /// Window entries are admission times in non-decreasing commit order.
    fn after_rps(&self, t: f64) -> f64 {
        let Some(rps) = self.max_rps else { return t };
        let rotated = self.window.partition_point(|&a| a <= t - RPS_WINDOW_MS);
        let in_window = self.window.len() - rotated;
        if (in_window as f64) + 1.0 <= rps {
            t
        } else {
            // room opens when the oldest in-window admission rotates out
            self.window[rotated] + RPS_WINDOW_MS
        }
    }

    /// Decide one request asking to fire at `trigger_ms`, having already
    /// waited `waited_ms` in this region's queue (queue-with-deadline
    /// budget). Commits nothing beyond idempotent garbage collection —
    /// call [`commit`](Self::commit) once the request actually executes,
    /// or [`reject`](Self::reject) when the denial is final for this
    /// region.
    pub fn admit(&mut self, trigger_ms: f64, waited_ms: f64) -> Admission {
        if self.unlimited() {
            return Admission::Admit { at_ms: trigger_ms };
        }
        self.gc(trigger_ms);
        let mut t = trigger_ms;
        loop {
            let t0 = t;
            t = self.after_outage(t);
            t = self.after_capacity(t);
            t = self.after_rps(t);
            if t <= t0 {
                break;
            }
        }
        if t == trigger_ms {
            return Admission::Admit { at_ms: t };
        }
        match self.throttle {
            ThrottlePolicy::Reject => Admission::Reject,
            ThrottlePolicy::Queue { max_wait_ms } => {
                if t.is_finite() && waited_ms + (t - trigger_ms) <= max_wait_ms {
                    Admission::Admit { at_ms: t }
                } else {
                    Admission::Reject
                }
            }
        }
    }

    /// Commit one admitted execution: it fires at `at_ms` after
    /// `waited_ms` of slot wait and keeps a concurrency slot busy until
    /// `busy_until_ms`.
    pub fn commit(&mut self, at_ms: f64, waited_ms: f64, busy_until_ms: f64) {
        self.admitted += 1;
        if waited_ms > 0.0 {
            self.queued += 1;
            self.queued_wait_ms += waited_ms;
        }
        if self.max_concurrent.is_some() {
            self.inflight.push(busy_until_ms);
        }
        if self.max_rps.is_some() {
            self.window.push_back(at_ms);
        }
    }

    /// Record one final denial in this region.
    pub fn reject(&mut self) {
        self.rejected += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(cap: Option<usize>, rps: Option<f64>) -> RegionSettings {
        let mut r = RegionSettings::new("r", 0.0);
        r.max_concurrent = cap;
        r.max_rps = rps;
        r
    }

    #[test]
    fn unlimited_admits_at_trigger() {
        let mut a = AdmissionControl::new(&spec(None, None), ThrottlePolicy::Reject, vec![]);
        assert!(a.unlimited());
        assert_eq!(a.admit(123.456, 0.0), Admission::Admit { at_ms: 123.456 });
    }

    #[test]
    fn concurrency_cap_rejects_then_frees() {
        let mut a = AdmissionControl::new(&spec(Some(2), None), ThrottlePolicy::Reject, vec![]);
        for _ in 0..2 {
            assert_eq!(a.admit(0.0, 0.0), Admission::Admit { at_ms: 0.0 });
            a.commit(0.0, 0.0, 1_000.0);
        }
        assert_eq!(a.admit(500.0, 0.0), Admission::Reject, "both slots busy");
        a.reject();
        assert_eq!(a.rejected, 1);
        // at 1 ms past completion both slots are free again
        assert_eq!(a.admit(1_000.5, 0.0), Admission::Admit { at_ms: 1_000.5 });
    }

    #[test]
    fn queue_policy_waits_for_the_earliest_slot() {
        let mut a = AdmissionControl::new(
            &spec(Some(1), None),
            ThrottlePolicy::Queue { max_wait_ms: 5_000.0 },
            vec![],
        );
        assert_eq!(a.admit(0.0, 0.0), Admission::Admit { at_ms: 0.0 });
        a.commit(0.0, 0.0, 2_000.0);
        // slot frees at 2 s → queued 1.5 s
        assert_eq!(a.admit(500.0, 0.0), Admission::Admit { at_ms: 2_000.0 });
        a.commit(2_000.0, 1_500.0, 9_000.0);
        assert_eq!(a.queued, 1);
        assert_eq!(a.queued_wait_ms, 1_500.0);
        // next would wait 6.5 s > the 5 s deadline → denied
        assert_eq!(a.admit(2_500.0, 0.0), Admission::Reject);
        // an already-spent budget also counts against the deadline
        assert_eq!(a.admit(8_000.0, 4_500.0), Admission::Reject);
        assert_eq!(a.admit(8_000.0, 3_000.0), Admission::Admit { at_ms: 9_000.0 });
    }

    #[test]
    fn denial_probing_never_frees_slots() {
        // regression: computing the would-be slot time for a denied
        // request must not garbage-collect in-flight entries at that
        // future time — a later request inside the busy window must still
        // see the region full
        let mut a = AdmissionControl::new(&spec(Some(1), None), ThrottlePolicy::Reject, vec![]);
        assert_eq!(a.admit(0.0, 0.0), Admission::Admit { at_ms: 0.0 });
        a.commit(0.0, 0.0, 1_000.0);
        assert_eq!(a.admit(100.0, 0.0), Admission::Reject);
        a.reject();
        assert_eq!(
            a.admit(200.0, 0.0),
            Admission::Reject,
            "the slot is still busy until 1 s — the earlier denial must not have freed it"
        );
    }

    #[test]
    fn queued_future_slots_stack_fifo() {
        // a queued admission reserves its future slot: the next asker must
        // wait behind BOTH the running and the queued execution
        let mut a = AdmissionControl::new(
            &spec(Some(1), None),
            ThrottlePolicy::Queue { max_wait_ms: 1e9 },
            vec![],
        );
        assert_eq!(a.admit(0.0, 0.0), Admission::Admit { at_ms: 0.0 });
        a.commit(0.0, 0.0, 1_000.0);
        assert_eq!(a.admit(100.0, 0.0), Admission::Admit { at_ms: 1_000.0 });
        a.commit(1_000.0, 900.0, 4_000.0);
        assert_eq!(
            a.admit(200.0, 0.0),
            Admission::Admit { at_ms: 4_000.0 },
            "must queue behind the already-reserved slot, not the running one"
        );
    }

    #[test]
    fn fractional_rps_floors_the_window() {
        // rps 2.5 means at most 2 admissions can coexist in one window
        let mut a = AdmissionControl::new(&spec(None, Some(2.5)), ThrottlePolicy::Reject, vec![]);
        for t in [0.0, 100.0] {
            assert_eq!(a.admit(t, 0.0), Admission::Admit { at_ms: t });
            a.commit(t, 0.0, 0.0);
        }
        assert_eq!(a.admit(200.0, 0.0), Admission::Reject, "a third would exceed 2.5/s");
    }

    #[test]
    fn zero_capacity_never_admits() {
        let mut a = AdmissionControl::new(
            &spec(Some(0), None),
            ThrottlePolicy::Queue { max_wait_ms: 1e12 },
            vec![],
        );
        assert_eq!(a.admit(0.0, 0.0), Admission::Reject, "infinite wait beats any deadline");
    }

    #[test]
    fn rps_window_rotates() {
        let mut a = AdmissionControl::new(&spec(None, Some(2.0)), ThrottlePolicy::Reject, vec![]);
        assert!(!a.unlimited());
        for t in [0.0, 100.0] {
            assert_eq!(a.admit(t, 0.0), Admission::Admit { at_ms: t });
            a.commit(t, 0.0, 0.0);
        }
        assert_eq!(a.admit(900.0, 0.0), Admission::Reject, "2 admissions in-window");
        // the t=0 admission rotates out after 1 s
        assert_eq!(a.admit(1_000.5, 0.0), Admission::Admit { at_ms: 1_000.5 });
    }

    #[test]
    fn rps_queue_waits_for_rotation() {
        let mut a = AdmissionControl::new(
            &spec(None, Some(1.0)),
            ThrottlePolicy::Queue { max_wait_ms: 10_000.0 },
            vec![],
        );
        assert_eq!(a.admit(0.0, 0.0), Admission::Admit { at_ms: 0.0 });
        a.commit(0.0, 0.0, 0.0);
        assert_eq!(a.admit(300.0, 0.0), Admission::Admit { at_ms: 1_000.0 });
    }

    #[test]
    fn outage_blocks_then_recovers() {
        let mut a = AdmissionControl::new(
            &spec(None, None),
            ThrottlePolicy::Reject,
            vec![(1_000.0, 3_000.0)],
        );
        assert_eq!(a.admit(999.0, 0.0), Admission::Admit { at_ms: 999.0 });
        assert_eq!(a.admit(1_000.0, 0.0), Admission::Reject, "window is [start, end)");
        assert_eq!(a.admit(2_999.0, 0.0), Admission::Reject);
        assert_eq!(a.admit(3_000.0, 0.0), Admission::Admit { at_ms: 3_000.0 }, "recovered");
    }

    #[test]
    fn queue_rides_out_an_outage() {
        let mut a = AdmissionControl::new(
            &spec(None, None),
            ThrottlePolicy::Queue { max_wait_ms: 2_500.0 },
            vec![(1_000.0, 3_000.0)],
        );
        assert_eq!(a.admit(900.0, 0.0), Admission::Admit { at_ms: 900.0 });
        assert_eq!(a.admit(1_200.0, 0.0), Admission::Admit { at_ms: 3_000.0 });
        assert_eq!(
            a.admit(1_200.0, 1_000.0),
            Admission::Reject,
            "1.8 s wait on top of 1 s already spent exceeds the 2.5 s deadline"
        );
    }

    #[test]
    fn admit_is_decision_only() {
        // deferring an admitted request and re-asking yields the same answer
        let mut a = AdmissionControl::new(
            &spec(Some(1), None),
            ThrottlePolicy::Queue { max_wait_ms: 1e9 },
            vec![],
        );
        assert_eq!(a.admit(0.0, 0.0), Admission::Admit { at_ms: 0.0 });
        a.commit(0.0, 0.0, 4_000.0);
        let first = a.admit(100.0, 0.0);
        let second = a.admit(100.0, 0.0);
        assert_eq!(first, second);
        assert_eq!(first, Admission::Admit { at_ms: 4_000.0 });
        assert_eq!(a.admitted, 1, "admit() itself commits nothing");
    }

    #[test]
    fn combined_constraints_fixpoint() {
        // capacity frees at 2 s but the rate window only opens at 2.5 s
        let mut a = AdmissionControl::new(
            &spec(Some(1), Some(1.0)),
            ThrottlePolicy::Queue { max_wait_ms: 1e9 },
            vec![],
        );
        assert_eq!(a.admit(1_500.0, 0.0), Admission::Admit { at_ms: 1_500.0 });
        a.commit(1_500.0, 0.0, 2_000.0);
        assert_eq!(a.admit(1_600.0, 0.0), Admission::Admit { at_ms: 2_500.0 });
    }
}
