//! Cloud substrate: AWS Lambda across the 19 container configurations.
//! Wraps one ground-truth `ConfigPool` per λ_m and assembles the full cloud
//! pipeline timing (Fig. 1a): upload → start (warm/cold) → compute → store.

use super::containers::{ConfigPool, StartKind};

/// Timing of one cloud execution, all absolute times in virtual ms.
#[derive(Debug, Clone, Copy)]
pub struct CloudExecution {
    pub kind: StartKind,
    pub container_id: u64,
    /// when the upload to S3 finished and the function was triggered
    pub triggered_at: f64,
    /// actual start latency used (warm or cold sample)
    pub start_ms: f64,
    pub comp_start: f64,
    pub comp_end: f64,
    /// when results are persisted in the output bucket
    pub stored_at: f64,
}

/// The cloud side of the platform: one pool per configuration.
pub struct CloudPlatform {
    pools: Vec<ConfigPool>,
}

impl CloudPlatform {
    pub fn new(n_configs: usize) -> Self {
        CloudPlatform { pools: (0..n_configs).map(|_| ConfigPool::new()).collect() }
    }

    /// Execute the cloud pipeline for config index `j`.
    ///
    /// `arrive` is ingestion time on the edge device; upload occupies
    /// [arrive, arrive+upld]. The container is selected at trigger time —
    /// the same instant the Predictor cannot observe, which is what makes
    /// warm/cold mispredictions possible.
    #[allow(clippy::too_many_arguments)]
    pub fn execute(
        &mut self,
        j: usize,
        arrive: f64,
        upld_ms: f64,
        comp_ms: f64,
        start_warm_ms: f64,
        start_cold_ms: f64,
        store_ms: f64,
        tidl_ms: f64,
    ) -> CloudExecution {
        let triggered_at = arrive + upld_ms;
        let pool = &mut self.pools[j];
        // Probe what the start kind will be to pick the right busy window.
        let warm = pool.peek_warm(triggered_at);
        let start_ms = if warm { start_warm_ms } else { start_cold_ms };
        let busy = start_ms + comp_ms;
        let (kind, container_id) = pool.invoke(triggered_at, busy, tidl_ms);
        debug_assert_eq!(kind == StartKind::Warm, warm);
        let comp_start = triggered_at + start_ms;
        let comp_end = comp_start + comp_ms;
        CloudExecution {
            kind,
            container_id,
            triggered_at,
            start_ms,
            comp_start,
            comp_end,
            stored_at: comp_end + store_ms,
        }
    }

    pub fn pool(&self, j: usize) -> &ConfigPool {
        &self.pools[j]
    }

    pub fn warm_total(&self) -> u64 {
        self.pools.iter().map(|p| p.warm_count).sum()
    }

    pub fn cold_total(&self) -> u64 {
        self.pools.iter().map(|p| p.cold_count).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_execution_cold_then_warm() {
        let mut c = CloudPlatform::new(3);
        let e1 = c.execute(1, 0.0, 500.0, 1000.0, 160.0, 1500.0, 550.0, 1e7);
        assert_eq!(e1.kind, StartKind::Cold);
        assert_eq!(e1.start_ms, 1500.0);
        assert_eq!(e1.stored_at, 500.0 + 1500.0 + 1000.0 + 550.0);
        // second arrives after the first completes -> warm on same config
        let e2 = c.execute(1, e1.comp_end, 500.0, 1000.0, 160.0, 1500.0, 550.0, 1e7);
        assert_eq!(e2.kind, StartKind::Warm);
        assert_eq!(e2.start_ms, 160.0);
    }

    #[test]
    fn configs_have_independent_pools() {
        let mut c = CloudPlatform::new(2);
        c.execute(0, 0.0, 10.0, 10.0, 1.0, 100.0, 1.0, 1e7);
        let e = c.execute(1, 5000.0, 10.0, 10.0, 1.0, 100.0, 1.0, 1e7);
        assert_eq!(e.kind, StartKind::Cold, "different config must cold start");
    }

    #[test]
    fn concurrent_triggers_scale_out() {
        let mut c = CloudPlatform::new(1);
        let e1 = c.execute(0, 0.0, 100.0, 5000.0, 160.0, 1500.0, 500.0, 1e7);
        // second triggered while first busy -> new container (cold)
        let e2 = c.execute(0, 50.0, 100.0, 5000.0, 160.0, 1500.0, 500.0, 1e7);
        assert_eq!(e1.kind, StartKind::Cold);
        assert_eq!(e2.kind, StartKind::Cold);
        assert_ne!(e1.container_id, e2.container_id);
        assert_eq!(c.cold_total(), 2);
    }

    #[test]
    fn e2e_latency_decomposition() {
        let mut c = CloudPlatform::new(1);
        let e = c.execute(0, 1000.0, 470.0, 1560.0, 163.0, 1500.0, 584.0, 1e7);
        let e2e = e.stored_at - 1000.0;
        assert!((e2e - (470.0 + 1500.0 + 1560.0 + 584.0)).abs() < 1e-9);
    }
}
