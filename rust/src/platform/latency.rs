//! Ground-truth latency sampler: the Rust mirror of
//! `python/compile/synthdata.py`, parameterized by the same values via
//! `meta.json`. Used by the generative workload path (live mode, sweeps
//! beyond the 600-input replay tables) and cross-checked against the
//! Python-emitted eval CSVs by integration tests.

use crate::config::{AppMeta, Meta};
use crate::util::rng::Pcg32;

/// All actual latency components for one input task (ms).
#[derive(Debug, Clone)]
pub struct TaskActuals {
    pub size: f64,
    pub bytes: f64,
    pub upld: f64,
    /// per memory-config compute time, one entry per config
    pub comp: Vec<f64>,
    pub start_w: f64,
    pub start_c: f64,
    pub store: f64,
    pub edge_comp: f64,
    pub iotup: f64,
    pub edge_store: f64,
}

impl TaskActuals {
    /// Warm cloud end-to-end latency for config index j: Eqn. (1).
    pub fn cloud_e2e(&self, j: usize, cold: bool) -> f64 {
        let start = if cold { self.start_c } else { self.start_w };
        self.upld + start + self.comp[j] + self.store
    }

    /// Edge end-to-end latency excluding queue wait: Eqn. (2).
    pub fn edge_e2e(&self) -> f64 {
        self.edge_comp + self.iotup + self.edge_store
    }
}

/// Generative sampler bound to one application's ground truth.
pub struct GroundTruthSampler<'a> {
    meta: &'a Meta,
    app: &'a AppMeta,
    rng: Pcg32,
}

impl<'a> GroundTruthSampler<'a> {
    pub fn new(meta: &'a Meta, app_name: &str, seed: u64) -> Self {
        GroundTruthSampler { meta, app: meta.app(app_name), rng: Pcg32::new(seed, 11) }
    }

    /// Draw an input size (pixels or bytes) from the app's distribution.
    pub fn sample_size(&mut self) -> f64 {
        let g = &self.app.ground_truth;
        self.rng
            .lognormal(g.size_log_mu, g.size_log_sigma)
            .clamp(g.size_min, g.size_max)
    }

    /// Noise-free compute work at the 1-vCPU knee.
    pub fn base_work_ms(&self, size: f64) -> f64 {
        let g = &self.app.ground_truth;
        g.comp_work_coeff * (size / g.comp_size_scale).powf(g.comp_work_exp)
    }

    /// Sample every latency component for a fresh input.
    pub fn sample_task(&mut self) -> TaskActuals {
        let size = self.sample_size();
        self.sample_task_with_size(size)
    }

    pub fn sample_task_with_size(&mut self, size: f64) -> TaskActuals {
        let g = &self.app.ground_truth;
        let bytes = size * g.bytes_per_unit;
        let upld = (g.upld_base_ms + g.upld_per_byte_ms * bytes)
            * self.rng.lognormal(0.0, g.upld_noise_sigma);
        let work = self.base_work_ms(size);
        let comp: Vec<f64> = self
            .meta
            .memory_configs_mb
            .iter()
            .map(|&m| {
                (work * self.meta.cpu_speed_factor(m)
                    * self.rng.lognormal(0.0, g.comp_noise_sigma))
                .max(1.0)
            })
            .collect();
        let start_w = self.rng.normal_min(g.start_warm_mean, g.start_warm_sigma, 5.0);
        let start_c = self.rng.normal_min(g.start_cold_mean, g.start_cold_sigma, 50.0);
        let store = self.rng.quantized_normal(g.store_mean, g.store_sigma, 100.0);
        let edge_comp = (g.edge_comp_base + g.edge_comp_slope * size)
            * self.rng.lognormal(0.0, g.edge_comp_noise_sigma);
        let iotup = if g.iotup_mean >= 0.0 {
            self.rng.normal_min(g.iotup_mean, g.iotup_sigma, 0.0)
        } else {
            0.0
        };
        let edge_store =
            self.rng.quantized_normal(g.edge_store_mean, g.edge_store_sigma, 100.0);
        TaskActuals {
            size,
            bytes,
            upld,
            comp,
            start_w,
            start_c,
            store,
            edge_comp,
            iotup,
            edge_store,
        }
    }

    /// Sample a fresh cold-start duration (per cold event, as the paper does).
    pub fn sample_cold_start(&mut self) -> f64 {
        let g = &self.app.ground_truth;
        self.rng.normal_min(g.start_cold_mean, g.start_cold_sigma, 50.0)
    }

    /// Sample a container idle lifetime T_idl.
    pub fn sample_tidl(&mut self) -> f64 {
        self.rng
            .normal_min(self.meta.tidl_mean_ms, self.meta.tidl_sigma_ms, 60_000.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::default_artifact_dir;
    use crate::util::stats::mean;

    fn meta() -> Meta {
        Meta::load(&default_artifact_dir()).unwrap()
    }

    #[test]
    fn component_means_match_table1() {
        let meta = meta();
        for (app, want_w, want_c, want_store) in
            [("ir", 162.0, 741.0, 549.0), ("fd", 163.0, 1500.0, 584.0), ("stt", 145.0, 1404.0, 533.0)]
        {
            let mut s = GroundTruthSampler::new(&meta, app, 1);
            let tasks: Vec<TaskActuals> = (0..4000).map(|_| s.sample_task()).collect();
            let w = mean(&tasks.iter().map(|t| t.start_w).collect::<Vec<_>>());
            let c = mean(&tasks.iter().map(|t| t.start_c).collect::<Vec<_>>());
            let st = mean(&tasks.iter().map(|t| t.store).collect::<Vec<_>>());
            assert!((w - want_w).abs() / want_w < 0.05, "{app} warm {w}");
            assert!((c - want_c).abs() / want_c < 0.05, "{app} cold {c}");
            assert!((st - want_store).abs() / want_store < 0.10, "{app} store {st}");
        }
    }

    #[test]
    fn comp_monotone_decreasing_in_memory_on_average() {
        let meta = meta();
        let mut s = GroundTruthSampler::new(&meta, "fd", 2);
        let tasks: Vec<TaskActuals> = (0..2000).map(|_| s.sample_task()).collect();
        let n = meta.memory_configs_mb.len();
        let means: Vec<f64> = (0..n)
            .map(|j| mean(&tasks.iter().map(|t| t.comp[j]).collect::<Vec<_>>()))
            .collect();
        for j in 1..n {
            assert!(means[j] < means[j - 1] * 1.02, "mean comp not decreasing at {j}");
        }
        assert!(means[0] > means[n - 1] * 2.0);
    }

    #[test]
    fn matches_python_eval_csv_moments() {
        // The python-generated replay table and the Rust generative path must
        // agree in distribution (cross-language calibration check).
        let meta = meta();
        for app in ["ir", "fd", "stt"] {
            let table = crate::util::csv::Table::load(&meta.eval_csv_path(app)).unwrap();
            let mut s = GroundTruthSampler::new(&meta, app, 3);
            let tasks: Vec<TaskActuals> = (0..6000).map(|_| s.sample_task()).collect();
            for (csv_col, get) in [
                ("upld", Box::new(|t: &TaskActuals| t.upld) as Box<dyn Fn(&TaskActuals) -> f64>),
                ("edge_comp", Box::new(|t: &TaskActuals| t.edge_comp)),
                ("comp_1536", Box::new(|t: &TaskActuals| t.comp[7])),
            ] {
                let csv_mean = mean(table.col(csv_col));
                let gen_mean = mean(&tasks.iter().map(|t| get(t)).collect::<Vec<_>>());
                let rel = (csv_mean - gen_mean).abs() / csv_mean;
                assert!(rel < 0.12, "{app}.{csv_col}: csv {csv_mean} vs gen {gen_mean}");
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let meta = meta();
        let mut a = GroundTruthSampler::new(&meta, "stt", 9);
        let mut b = GroundTruthSampler::new(&meta, "stt", 9);
        for _ in 0..50 {
            let (x, y) = (a.sample_task(), b.sample_task());
            assert_eq!(x.size, y.size);
            assert_eq!(x.comp, y.comp);
        }
    }

    #[test]
    fn tidl_positive_and_near_27min(){
        let meta = meta();
        let mut s = GroundTruthSampler::new(&meta, "fd", 4);
        let xs: Vec<f64> = (0..2000).map(|_| s.sample_tidl()).collect();
        let m = mean(&xs);
        assert!((m - 27.0 * 60e3).abs() < 60e3, "tidl mean {m}");
        assert!(xs.iter().all(|&x| x >= 60_000.0));
    }
}
