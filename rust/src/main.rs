//! `skedge` — launcher for the dynamic task placement framework.
//!
//! Subcommands:
//!   tables  --id <table1|table2|table3|table4|table5|edgeonly|baselines|
//!                 tidl|configsel|ablations|all> [--xla]
//!   figures --id <fig3|fig4|fig5|fig6>
//!   sim     --app <ir|fd|stt> --objective <cost-min|latency-min>
//!           --set 1536,1664,2048 [--alpha A] [--deadline MS] [--cmax $]
//!           [--n N] [--seed S] [--backend xla|native] [--generate]
//!   live    --app <ir|fd|stt> [--set ...] [--n N] [--scale 0.05]
//!           [--runs R] [--backend xla|native]
//!   report                       # run every experiment in order
//!
//! `--xla` / `--backend xla` put the AOT-compiled artifact (PJRT) on the
//! request path; the default native backend needs no artifacts beyond
//! meta.json.

use anyhow::{bail, Result};

use skedge::cli::Args;
use skedge::config::{
    default_artifact_dir, ExperimentSettings, Meta, Objective, PredictorBackendKind,
};
use skedge::experiments;
use skedge::live::{self, LiveConfig};
use skedge::metrics::{budget_metrics, deadline_violations};
use skedge::sim;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let artifact_dir = args.get_or("artifacts", &default_artifact_dir()).to_string();
    match args.subcommand.as_str() {
        "" | "help" => {
            print!("{}", HELP);
            Ok(())
        }
        "tables" | "figures" => {
            let meta = Meta::load(&artifact_dir)?;
            let id = args.get_or("id", "all");
            let xla = args.has_switch("xla");
            if id == "all" {
                for id in experiments::ALL_EXPERIMENTS {
                    experiments::run_experiment(&meta, id, xla)?;
                }
            } else {
                experiments::run_experiment(&meta, id, xla)?;
            }
            Ok(())
        }
        "report" => {
            let meta = Meta::load(&artifact_dir)?;
            for id in experiments::ALL_EXPERIMENTS {
                experiments::run_experiment(&meta, id, args.has_switch("xla"))?;
            }
            Ok(())
        }
        "sim" => {
            let meta = Meta::load(&artifact_dir)?;
            let settings = settings_from_args(&meta, &args)?;
            let o = sim::run(&meta, &settings)?;
            print_run_summary(&meta, &settings, &o.summary, &o.records);
            Ok(())
        }
        "live" => {
            let meta = Meta::load(&artifact_dir)?;
            let mut settings = settings_from_args(&meta, &args)?;
            settings.objective = Objective::LatencyMin;
            let scale = args.f64("scale")?.unwrap_or(0.05);
            let runs = args.usize("runs")?.unwrap_or(1);
            for r in 0..runs {
                let cfg = LiveConfig {
                    settings: settings.clone().with_seed(settings.seed + r as u64),
                    time_scale: scale,
                    fixed_rate: true,
                };
                let o = live::run(&meta, &cfg)?;
                println!("-- live run {} ({:.1}s wall) --", r + 1, o.wall_seconds);
                print_run_summary(&meta, &settings, &o.summary, &o.records);
            }
            Ok(())
        }
        other => bail!("unknown subcommand `{other}` (try `skedge help`)"),
    }
}

fn settings_from_args(meta: &Meta, args: &Args) -> Result<ExperimentSettings> {
    let app = args.get_or("app", "fd").to_string();
    if !meta.apps.contains_key(&app) {
        bail!("unknown app `{app}`");
    }
    let objective = Objective::parse(args.get_or("objective", "latency-min"))?;
    let set = match args.get("set") {
        Some(s) => ExperimentSettings::parse_config_set(s)?,
        None => experiments::best_latmin_set(&app),
    };
    let mut settings = ExperimentSettings::new(&app, objective, &set);
    settings.deadline_ms = args.f64("deadline")?;
    settings.cmax = args.f64("cmax")?;
    settings.alpha = args.f64("alpha")?;
    settings.n_inputs = args.usize("n")?;
    settings.seed = args.u64_or("seed", 2020)?;
    settings.replay = !args.has_switch("generate");
    settings.risk_factor = args.f64("risk")?.unwrap_or(0.0);
    settings.backend = PredictorBackendKind::parse(args.get_or("backend", "native"))?;
    Ok(settings)
}

fn print_run_summary(
    meta: &Meta,
    settings: &ExperimentSettings,
    summary: &skedge::metrics::Summary,
    records: &[skedge::metrics::TaskRecord],
) {
    let am = meta.app(&settings.app);
    println!("app            : {}", settings.app);
    println!("objective      : {:?}", settings.objective);
    println!(
        "tasks          : {} ({} edge, {} cloud)",
        summary.n, summary.edge_count, summary.cloud_count
    );
    println!(
        "avg e2e        : {:.3} s (predicted {:.3} s, err {:.2}%)",
        summary.avg_actual_e2e_ms / 1e3,
        summary.avg_predicted_e2e_ms / 1e3,
        summary.latency_prediction_error_pct()
    );
    println!(
        "total cost     : ${:.8} (predicted ${:.8}, err {:.2}%)",
        summary.total_actual_cost,
        summary.total_predicted_cost,
        summary.cost_prediction_error_pct()
    );
    match settings.objective {
        Objective::CostMin => {
            let delta = settings.deadline_ms.unwrap_or(am.deadline_ms);
            let (pct, avg) = deadline_violations(records, delta);
            println!(
                "deadline δ     : {:.1} s — {:.2}% violated (avg {:.1} ms over)",
                delta / 1e3,
                pct,
                avg
            );
        }
        Objective::LatencyMin => {
            let cmax = settings.cmax.unwrap_or(am.cmax);
            let (viol, used) = budget_metrics(records, cmax);
            println!(
                "budget C_max   : ${cmax:.4e} — {viol:.2}% constraints violated, {used:.1}% budget used"
            );
        }
    }
    println!(
        "warm/cold      : {} warm, {} cold, {} mispredicted",
        summary.cloud_actual_warm, summary.cloud_actual_cold, summary.warm_cold_mismatches
    );
}

const HELP: &str = r#"skedge — dynamic task placement for edge-cloud serverless platforms
(reproduction of Das et al., 2020; see DESIGN.md)

USAGE:
  skedge tables  --id <experiment> [--xla]     regenerate a paper table
  skedge figures --id <fig3|fig4|fig5|fig6>    regenerate figure data (CSV)
  skedge report  [--xla]                       run every experiment
  skedge sim     --app fd --objective latency-min --set 1536,1664,2048
                 [--alpha A] [--deadline MS] [--cmax $] [--n N] [--risk R]
                 [--backend xla|native] [--generate] [--seed S]
  skedge live    --app fd [--set ...] [--scale 0.05] [--runs 4]
                 [--backend xla|native]

Experiments: table1 table2 fig3 fig4 table3 fig5 table4 fig6 table5
             edgeonly baselines tidl configsel ablations | all

Artifacts are read from ./artifacts (override: --artifacts DIR or
$SKEDGE_ARTIFACTS). Run `make artifacts` first.
"#;
