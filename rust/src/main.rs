//! `skedge` — launcher for the dynamic task placement framework.
//!
//! Subcommands:
//!   tables  --id <table1|table2|table3|table4|table5|edgeonly|baselines|
//!                 tidl|configsel|ablations|all> [--xla]
//!   figures --id <fig3|fig4|fig5|fig6>
//!   sim     --app <ir|fd|stt> --objective <cost-min|latency-min>
//!           --set 1536,1664,2048 [--alpha A] [--deadline MS] [--cmax $]
//!           [--n N] [--seed S] [--backend xla|native] [--generate]
//!           [--feedback off|observe] [--record PATH|off] [--replay PATH]
//!   fleet   --devices 1000 [--scenario poisson|diurnal|diurnal-tz|burst|
//!                           churn|flash|drift|outage]
//!           [--duration-s 30] [--shards 4] [--apps ir:0.4,fd:0.4,stt:0.2]
//!           [--objective O] [--seed S] [--rate-mult M] [--epoch-ms E]
//!           [--drift-sigma S] [--outage-frac F] [--outage-period-s P]
//!           [--outage-down-s D] [--feedback off|observe]
//!           [--merge per-region|global]
//!           [--topology duo|triad|name:rtt[:price[:tz_s[:w]]],...]
//!           [--cil private|hub] [--cross-ms 60] [--route-jitter S]
//!           [--move-frac F] [--move-at-s T]
//!           [--region-cap N|name:N,...] [--region-rps R|name:R,...]
//!           [--throttle reject|queue[:WAIT_S]] [--failover]
//!           [--outage name:START_S-END_S,...]
//!           [--record PATH|off] [--replay PATH] [--stream-metrics]
//!           [--metrics PATH] [--metrics-prom PATH] [--metrics-window-ms W]
//!           [--profile]
//!   live    --app <ir|fd|stt> [--set ...] [--n N] [--scale 0.05]
//!           [--runs R] [--backend xla|native] [--feedback off|observe]
//!           [--record PATH]
//!   analyze --input PATH [--window-ms W] [--deadline MS]
//!   report                       # run every experiment in order
//!
//! `--xla` / `--backend xla` put the AOT-compiled artifact (PJRT) on the
//! request path; the default native backend needs no artifacts beyond
//! meta.json.

use anyhow::{bail, Result};

use skedge::cli::Args;
use skedge::config::{
    default_artifact_dir, CilMode, ExperimentSettings, FabricSpec, FeedbackMode, FleetScenario,
    FleetSettings, MergeMode, Meta, Objective, PredictorBackendKind, ThrottlePolicy, TopologySpec,
};
use skedge::experiments;
use skedge::fleet;
use skedge::live::{self, LiveConfig};
use skedge::metrics::{budget_metrics, deadline_violations};
use skedge::sim;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let artifact_dir = args.get_or("artifacts", &default_artifact_dir()).to_string();
    match args.subcommand.as_str() {
        "" | "help" => {
            print!("{}", HELP);
            Ok(())
        }
        "tables" | "figures" => {
            let meta = Meta::load(&artifact_dir)?;
            let id = args.get_or("id", "all");
            let xla = args.has_switch("xla");
            if id == "all" {
                for id in experiments::ALL_EXPERIMENTS {
                    experiments::run_experiment(&meta, id, xla)?;
                }
            } else {
                experiments::run_experiment(&meta, id, xla)?;
            }
            Ok(())
        }
        "report" => {
            let meta = Meta::load(&artifact_dir)?;
            for id in experiments::ALL_EXPERIMENTS {
                experiments::run_experiment(&meta, id, args.has_switch("xla"))?;
            }
            Ok(())
        }
        "sim" => {
            let meta = Meta::load(&artifact_dir)?;
            let settings = settings_from_args(&meta, &args)?;
            let record_path = record_path_arg(&args);
            let replay_times = match args.get("replay") {
                Some(path) => {
                    let rows = skedge::obs::read_arrivals(path)?;
                    // the simulator is the single paper device: the trace
                    // must be single-device and name the app under test
                    if let Some(app) = skedge::obs::per_device_apps(&rows, 1)?[0].as_deref() {
                        if app != settings.app {
                            bail!(
                                "trace `{path}` records app `{app}` but --app is `{}` \
                                 (pass --app {app})",
                                settings.app
                            );
                        }
                    }
                    Some(skedge::obs::per_device_times(&rows, 1)?.remove(0))
                }
                None => None,
            };
            let (o, events) = match (&replay_times, &record_path) {
                (None, None) => (sim::run(&meta, &settings)?, Vec::new()),
                (None, Some(_)) => sim::run_recorded(&meta, &settings)?,
                (Some(t), None) => (sim::run_with_arrivals(&meta, &settings, t)?, Vec::new()),
                (Some(t), Some(_)) => sim::run_recorded_with_arrivals(&meta, &settings, t)?,
            };
            print_run_summary(&meta, &settings, &o.summary, &o.records);
            write_run_metrics(&meta, &settings, &o.records, &args)?;
            write_recording(record_path.as_deref(), &events)?;
            Ok(())
        }
        "fleet" => {
            let meta = Meta::load(&artifact_dir)?;
            let mut fs = fleet_settings_from_args(&args)?;
            let record_path = record_path_arg(&args);
            fs = fs.with_recording(record_path.is_some());
            fs = fs.with_stream_metrics(args.has_switch("stream-metrics"));
            let metrics_path = args.get("metrics").map(str::to_string);
            let prom_path = args.get("metrics-prom").map(str::to_string);
            if metrics_path.is_some() || prom_path.is_some() {
                fs = fs.with_metrics(true);
            }
            if let Some(w) = args.f64("metrics-window-ms")? {
                fs = fs.with_metrics_window_ms(w);
            }
            if let Some(path) = args.get("replay") {
                match args.get("scenario") {
                    None | Some("replay") => {}
                    Some(s) => bail!(
                        "--replay drives arrivals from the trace; `--scenario {s}` conflicts"
                    ),
                }
                let (rows, moves) = skedge::obs::read_replay(path)?;
                if args.get("devices").is_none() {
                    // size the fleet to the trace unless told otherwise
                    fs.devices = rows.iter().map(|r| r.device + 1).max().unwrap_or(1);
                }
                fs = fs.with_replay_trace(std::sync::Arc::new(rows));
                if !moves.is_empty() {
                    fs = fs.with_replay_moves(std::sync::Arc::new(moves));
                }
            }
            // time only the sharded run, not single-threaded workload
            // generation, so the printed tasks/s reflects threading
            let inits = fleet::scenario::build_fleet(&meta, &fs)?;
            let t0 = skedge::obs::profile::Stopwatch::start();
            let mut o = fleet::shard::run_fleet(&meta, inits, &fs)?;
            if fs.record_events {
                o.summary.fold_recorded_events(o.events.len() as u64);
            }
            print_fleet_summary(&fs, &o, t0.elapsed_s());
            if let Some(t) = &o.telemetry {
                if let Some(path) = &metrics_path {
                    t.write_file(path)?;
                    println!("metrics        : {} window cells -> {path}", t.n_cells());
                }
                if let Some(path) = &prom_path {
                    std::fs::write(path, t.to_prometheus())
                        .map_err(|e| anyhow::anyhow!("cannot write `{path}`: {e}"))?;
                    println!("metrics        : prometheus snapshot -> {path}");
                }
            }
            if args.has_switch("profile") {
                print!("{}", o.profile.render());
            }
            write_recording(record_path.as_deref(), &o.events)?;
            Ok(())
        }
        "analyze" => {
            let path = args.req("input")?;
            let events = skedge::obs::read_events_file(path)?;
            let mut opts = skedge::obs::AnalyzeOptions::default();
            if let Some(w) = args.f64("window-ms")? {
                opts.window_ms = w;
            }
            // SLO deadlines: artifact metadata when available; --deadline
            // overrides every app seen in the stream (and is the only
            // source when no artifacts are around)
            if let Ok(meta) = Meta::load(&artifact_dir) {
                for (name, app) in &meta.apps {
                    opts.deadlines.insert(name.clone(), app.deadline_ms);
                }
            }
            if let Some(d) = args.f64("deadline")? {
                let apps: std::collections::BTreeSet<String> =
                    events.iter().filter_map(|e| e.meta().map(|m| m.app.clone())).collect();
                for app in apps {
                    opts.deadlines.insert(app, d);
                }
            }
            print!("{}", skedge::obs::render_report(&events, &opts));
            Ok(())
        }
        "live" => {
            let meta = Meta::load(&artifact_dir)?;
            let mut settings = settings_from_args(&meta, &args)?;
            settings.objective = Objective::LatencyMin;
            let scale = args.f64("scale")?.unwrap_or(0.05);
            let runs = args.usize("runs")?.unwrap_or(1);
            let record_path = record_path_arg(&args);
            for r in 0..runs {
                let cfg = LiveConfig {
                    settings: settings.clone().with_seed(settings.seed + r as u64),
                    time_scale: scale,
                    fixed_rate: true,
                };
                let (o, events) = match &record_path {
                    Some(_) => live::run_recorded(&meta, &cfg)?,
                    None => (live::run(&meta, &cfg)?, Vec::new()),
                };
                println!("-- live run {} ({:.1}s wall) --", r + 1, o.wall_seconds);
                println!("latency tail   : {}", fmt_latency(&o.latency));
                match &o.wall_latency {
                    Some(w) => println!(
                        "wall tail      : p50 {:.3} s  p95 {:.3} s  p99 {:.3} s \
                         (measured; mean {:.3} s, pred err {:.2}%)",
                        w.p50 / 1e3,
                        w.p95 / 1e3,
                        w.p99 / 1e3,
                        o.wall_avg_e2e_ms / 1e3,
                        o.wall_latency_prediction_error_pct()
                    ),
                    None => println!("wall tail      : n/a (no tasks measured)"),
                }
                print_run_summary(&meta, &settings, &o.summary, &o.records);
                if let Some(mpath) = args.get("metrics") {
                    // one series per run, mirroring the recording suffix
                    let mpath =
                        if runs > 1 { format!("{mpath}.run{}", r + 1) } else { mpath.to_string() };
                    write_run_metrics_path(&meta, &settings, &o.records, &args, &mpath)?;
                }
                if let Some(path) = &record_path {
                    // one stream per run so repeats don't clobber each other
                    let path =
                        if runs > 1 { format!("{path}.run{}", r + 1) } else { path.clone() };
                    write_recording(Some(&path), &events)?;
                }
            }
            Ok(())
        }
        other => bail!("unknown subcommand `{other}` (try `skedge help`)"),
    }
}

fn fleet_settings_from_args(args: &Args) -> Result<FleetSettings> {
    let devices = args.usize("devices")?.unwrap_or(100);
    let mut fs = FleetSettings::new(devices);
    if let Some(name) = args.get("scenario") {
        fs.scenario = FleetScenario::parse(name)?;
    }
    // scenario parameter overrides (apply to whichever scenario is active)
    if let Some(p) = args.f64("period-s")? {
        match &mut fs.scenario {
            FleetScenario::Diurnal { period_ms, .. }
            | FleetScenario::DiurnalTz { period_ms, .. }
            | FleetScenario::Burst { period_ms, .. } => *period_ms = p * 1000.0,
            _ => bail!("--period-s only applies to diurnal/diurnal-tz/burst scenarios"),
        }
    }
    if let Some(a) = args.f64("amplitude")? {
        match &mut fs.scenario {
            FleetScenario::Diurnal { amplitude, .. }
            | FleetScenario::DiurnalTz { amplitude, .. } => *amplitude = a,
            _ => bail!("--amplitude only applies to diurnal scenarios"),
        }
    }
    if let Some(n) = args.usize("burst-size")? {
        match &mut fs.scenario {
            FleetScenario::Burst { size, .. } => *size = n,
            _ => bail!("--burst-size only applies to the burst scenario"),
        }
    }
    if let Some(s) = args.f64("drift-sigma")? {
        match &mut fs.scenario {
            FleetScenario::Drift { sigma } => *sigma = s,
            _ => bail!("--drift-sigma only applies to the drift scenario"),
        }
    }
    if let Some(f) = args.f64("outage-frac")? {
        match &mut fs.scenario {
            FleetScenario::Outage { frac, .. } => *frac = f,
            _ => bail!("--outage-frac only applies to the outage scenario"),
        }
    }
    if let Some(p) = args.f64("outage-period-s")? {
        match &mut fs.scenario {
            FleetScenario::Outage { period_ms, .. } => *period_ms = p * 1000.0,
            _ => bail!("--outage-period-s only applies to the outage scenario"),
        }
    }
    if let Some(d) = args.f64("outage-down-s")? {
        match &mut fs.scenario {
            FleetScenario::Outage { down_ms, .. } => *down_ms = d * 1000.0,
            _ => bail!("--outage-down-s only applies to the outage scenario"),
        }
    }
    if let Some(d) = args.f64("duration-s")? {
        fs.duration_ms = d * 1000.0;
    }
    if let Some(n) = args.usize("shards")? {
        fs.shards = n;
    }
    if let Some(e) = args.f64("epoch-ms")? {
        fs.epoch_ms = e;
    }
    fs.seed = args.u64_or("seed", fs.seed)?;
    if let Some(mix) = args.get("apps") {
        fs.app_mix = FleetSettings::parse_app_mix(mix)?;
    }
    if let Some(o) = args.get("objective") {
        fs.objective = Objective::parse(o)?;
    }
    if let Some(m) = args.f64("rate-mult")? {
        fs.rate_mult = m;
    }
    if let Some(f) = args.get("feedback") {
        fs.feedback = FeedbackMode::parse(f)?;
    }
    if let Some(m) = args.get("merge") {
        fs = fs.with_merge(MergeMode::parse(m)?);
    }
    // network fabric: --fabric SPEC, with --uplink-mbps / --access-latency-ms
    // as single-knob shorthands; any of the three enables the model
    let mut fabric = args.get("fabric").map(FabricSpec::parse).transpose()?;
    if let Some(mbps) = args.f64("uplink-mbps")? {
        fabric.get_or_insert(FabricSpec::UNCAPPED).uplink_mbps = mbps;
    }
    if let Some(ms) = args.f64("access-latency-ms")? {
        fabric.get_or_insert(FabricSpec::UNCAPPED).access_latency_ms = ms;
    }
    if let Some(spec) = fabric {
        spec.validate()?;
        fs = fs.with_fabric(spec);
    }
    if let Some(spec) = args.get("topology") {
        let mut topo = TopologySpec::parse(spec)?;
        if let Some(mode) = args.get("cil") {
            topo.cil_mode = CilMode::parse(mode)?;
        }
        if let Some(p) = args.f64("cross-ms")? {
            topo.cross_penalty_ms = p;
        }
        if let Some(s) = args.f64("route-jitter")? {
            topo.routing_jitter_sigma = s;
        }
        match (args.f64("move-frac")?, args.f64("move-at-s")?) {
            (Some(f), at) => {
                let at = at.unwrap_or(fs.duration_ms / 2.0 / 1000.0);
                topo = topo.with_mobility(f, at * 1000.0);
            }
            (None, Some(_)) => bail!("--move-at-s requires --move-frac"),
            (None, None) => {}
        }
        // region resilience: capacity limits, throttling, failover, outages
        if let Some(cap) = args.get("region-cap") {
            topo.apply_caps(cap)?;
        }
        if let Some(rps) = args.get("region-rps") {
            topo.apply_rps(rps)?;
        }
        if let Some(t) = args.get("throttle") {
            topo.throttle = ThrottlePolicy::parse(t)?;
        }
        if args.has_switch("failover") {
            topo.failover = true;
        }
        if let Some(windows) = args.get("outage") {
            topo.parse_outages(windows)?;
        }
        topo.validate()?;
        fs.topology = Some(topo);
    } else if ["cil", "cross-ms", "route-jitter", "move-frac", "move-at-s", "region-cap",
               "region-rps", "throttle", "outage"]
        .iter()
        .any(|k| args.get(k).is_some())
        || args.has_switch("failover")
    {
        bail!(
            "--cil/--cross-ms/--route-jitter/--move-frac/--move-at-s/--region-cap/\
             --region-rps/--throttle/--failover/--outage require --topology"
        );
    }
    Ok(fs)
}

/// `--record PATH`; the explicit `off` sentinel disables recording.
fn record_path_arg(args: &Args) -> Option<String> {
    args.get("record").filter(|p| *p != "off").map(str::to_string)
}

/// `--metrics PATH` for the single-device runners (sim/live): build the
/// windowed series from the retained records — one device, one "cloud"
/// region — and write the JSONL file.
fn write_run_metrics(
    meta: &Meta,
    settings: &ExperimentSettings,
    records: &[skedge::metrics::TaskRecord],
    args: &Args,
) -> Result<()> {
    match args.get("metrics") {
        Some(path) => write_run_metrics_path(meta, settings, records, args, path),
        None => Ok(()),
    }
}

fn write_run_metrics_path(
    meta: &Meta,
    settings: &ExperimentSettings,
    records: &[skedge::metrics::TaskRecord],
    args: &Args,
    path: &str,
) -> Result<()> {
    let window_ms = args.f64("metrics-window-ms")?.filter(|w| *w > 0.0).unwrap_or(5_000.0);
    let cfg = skedge::obs::TelemetryCfg {
        window_ms,
        n_configs: meta.memory_configs_mb.len(),
        apps: std::sync::Arc::new(vec![settings.app.clone()]),
        regions: std::sync::Arc::new(vec!["cloud".to_string()]),
        app_idx: std::sync::Arc::new(vec![0]),
    };
    let deadline = settings.deadline_ms.unwrap_or(meta.app(&settings.app).deadline_ms);
    let t = skedge::obs::Telemetry::from_records(&cfg, records, |_| 0, |_| deadline);
    t.write_file(path)?;
    println!("metrics        : {} window cells -> {path}", t.n_cells());
    Ok(())
}

/// Write a recorded event stream to disk (no-op when recording is off).
fn write_recording(path: Option<&str>, events: &[skedge::obs::TaskEvent]) -> Result<()> {
    if let Some(path) = path {
        skedge::obs::write_events_file(path, events)?;
        println!("events         : {} recorded -> {path}", events.len());
    }
    Ok(())
}

/// Join the nonzero counter segments of a status line; `None` when every
/// counter is zero — the uniform elision rule for resilience/feedback
/// lines (zero-valued fields dropped, all-zero lines dropped entirely).
fn nonzero_counters(parts: Vec<(u64, String)>) -> Option<String> {
    let shown: Vec<String> = parts.into_iter().filter(|(v, _)| *v > 0).map(|(_, s)| s).collect();
    if shown.is_empty() {
        None
    } else {
        Some(shown.join(", "))
    }
}

fn print_fleet_summary(fs: &FleetSettings, o: &fleet::FleetOutcome, wall_s: f64) {
    let s = &o.summary;
    let mut app_counts: std::collections::BTreeMap<&str, usize> = Default::default();
    for d in &o.device_summaries {
        *app_counts.entry(d.app.as_str()).or_default() += 1;
    }
    // streaming mode retains no per-device summaries to count apps from
    let mix = if o.device_summaries.is_empty() {
        String::new()
    } else {
        let counts =
            app_counts.iter().map(|(a, n)| format!("{a} {n}")).collect::<Vec<_>>().join(" / ");
        format!(" ({counts})")
    };
    println!("fleet          : {} devices{mix}, scenario {}", s.n_devices, fs.scenario.label());
    if fs.stream_metrics {
        println!(
            "metrics        : streaming (mergeable summaries; sketch quantiles within \
             {:.0}% of exact)",
            skedge::obs::SKETCH_ALPHA * 100.0
        );
    }
    if let Some(topo) = &fs.topology {
        println!(
            "topology       : {} regions, {} CIL",
            topo.n_regions(),
            topo.cil_mode.label()
        );
    }
    if let Some(f) = &fs.fabric {
        let cap = |mbps: f64| {
            if mbps.is_infinite() {
                "uncapped".to_string()
            } else {
                format!("{mbps} Mbps")
            }
        };
        println!(
            "fabric         : uplink {}, access {} (+{} ms latency)",
            cap(f.uplink_mbps),
            cap(f.access_mbps),
            f.access_latency_ms
        );
    }
    if fs.feedback != FeedbackMode::Off {
        let obs: u64 = o.hub_observations.iter().sum();
        let retr: u64 = o.hub_retractions.iter().sum();
        let counters = nonzero_counters(vec![
            (obs, format!("{obs} hub observations")),
            (retr, format!("{retr} hub retractions")),
        ]);
        match counters {
            Some(c) => println!("feedback       : {} ({c})", fs.feedback.label()),
            None => println!("feedback       : {}", fs.feedback.label()),
        }
    }
    println!(
        "tasks          : {} ({} edge, {} cloud) over {:.0} virtual s",
        s.n_tasks,
        s.edge_count,
        s.cloud_count,
        o.sim_end_ms / 1e3
    );
    match &s.latency {
        Some(l) => println!(
            "latency        : p50 {:.3} s  p95 {:.3} s  p99 {:.3} s  (mean {:.3} s)",
            l.p50 / 1e3,
            l.p95 / 1e3,
            l.p99 / 1e3,
            s.avg_e2e_ms / 1e3
        ),
        None => println!("latency        : n/a (no tasks served)"),
    }
    let queued_total: u64 = o.region_queued.iter().sum();
    let resilience = nonzero_counters(vec![
        (
            s.rejected_count as u64,
            format!(
                "{} rejected ({:.2}%)",
                s.rejected_count,
                s.rejected_count as f64 / s.n_tasks.max(1) as f64 * 100.0
            ),
        ),
        (s.failover_hops_total, format!("{} failover hops", s.failover_hops_total)),
        (queued_total, format!("{queued_total} queued admissions")),
    ]);
    if let Some(line) = resilience {
        println!("resilience     : {line}");
    }
    println!("deadlines      : {:.2}% violated", s.deadline_violation_pct);
    println!(
        "cost           : ${:.8} actual (${:.8} predicted)",
        s.total_actual_cost, s.total_predicted_cost
    );
    println!(
        "warm/cold      : {} warm, {} cold, {} CIL mispredictions",
        s.cloud_actual_warm, s.cloud_actual_cold, s.warm_cold_mismatches
    );
    println!(
        "pool pressure  : max {} live containers in one pool, peak edge queue {}",
        s.max_pool_high_water, s.peak_edge_queue
    );
    if s.regions.len() > 1 {
        for (br, &hub) in s.regions.iter().zip(&o.hub_updates) {
            let cloud = br.cloud_count.max(1) as f64;
            let resilience = if br.rejected > 0 || br.failover_in > 0 {
                format!(", {} rejected, {} failed over in", br.rejected, br.failover_in)
            } else {
                String::new()
            };
            println!(
                "  region {:<10}: {:>6} cloud tasks, {:>5.1}% warm, {:>5.1}% mispredicted, pool max {}, {} hub updates{resilience}",
                br.name,
                br.cloud_count,
                br.warm as f64 / cloud * 100.0,
                br.mismatches as f64 / cloud * 100.0,
                br.max_pool_high_water,
                hub,
            );
        }
    }
    println!(
        "throughput     : {:.0} tasks/s wall ({} shards, {:.1} s)",
        s.n_tasks as f64 / wall_s.max(1e-9),
        fs.shards,
        wall_s
    );
    println!("fingerprint    : {:016x}", s.fingerprint);
}

fn fmt_latency(l: &Option<skedge::fleet::LatencyPercentiles>) -> String {
    match l {
        Some(l) => format!(
            "p50 {:.3} s  p95 {:.3} s  p99 {:.3} s",
            l.p50 / 1e3,
            l.p95 / 1e3,
            l.p99 / 1e3
        ),
        None => "n/a (no tasks served)".to_string(),
    }
}

fn settings_from_args(meta: &Meta, args: &Args) -> Result<ExperimentSettings> {
    let app = args.get_or("app", "fd").to_string();
    if !meta.apps.contains_key(&app) {
        bail!("unknown app `{app}`");
    }
    let objective = Objective::parse(args.get_or("objective", "latency-min"))?;
    let set = match args.get("set") {
        Some(s) => ExperimentSettings::parse_config_set(s)?,
        None => experiments::best_latmin_set(&app),
    };
    let mut settings = ExperimentSettings::new(&app, objective, &set);
    settings.deadline_ms = args.f64("deadline")?;
    settings.cmax = args.f64("cmax")?;
    settings.alpha = args.f64("alpha")?;
    settings.n_inputs = args.usize("n")?;
    settings.seed = args.u64_or("seed", 2020)?;
    settings.replay = !args.has_switch("generate");
    settings.risk_factor = args.f64("risk")?.unwrap_or(0.0);
    settings.backend = PredictorBackendKind::parse(args.get_or("backend", "native"))?;
    settings.feedback = FeedbackMode::parse(args.get_or("feedback", "off"))?;
    Ok(settings)
}

fn print_run_summary(
    meta: &Meta,
    settings: &ExperimentSettings,
    summary: &skedge::metrics::Summary,
    records: &[skedge::metrics::TaskRecord],
) {
    let am = meta.app(&settings.app);
    println!("app            : {}", settings.app);
    println!("objective      : {:?}", settings.objective);
    println!(
        "tasks          : {} ({} edge, {} cloud)",
        summary.n, summary.edge_count, summary.cloud_count
    );
    println!(
        "avg e2e        : {:.3} s (predicted {:.3} s, err {:.2}%)",
        summary.avg_actual_e2e_ms / 1e3,
        summary.avg_predicted_e2e_ms / 1e3,
        summary.latency_prediction_error_pct()
    );
    println!(
        "total cost     : ${:.8} (predicted ${:.8}, err {:.2}%)",
        summary.total_actual_cost,
        summary.total_predicted_cost,
        summary.cost_prediction_error_pct()
    );
    match settings.objective {
        Objective::CostMin => {
            let delta = settings.deadline_ms.unwrap_or(am.deadline_ms);
            let (pct, avg) = deadline_violations(records, delta);
            println!(
                "deadline δ     : {:.1} s — {:.2}% violated (avg {:.1} ms over)",
                delta / 1e3,
                pct,
                avg
            );
        }
        Objective::LatencyMin => {
            let cmax = settings.cmax.unwrap_or(am.cmax);
            let (viol, used) = budget_metrics(records, cmax);
            println!(
                "budget C_max   : ${cmax:.4e} — {viol:.2}% constraints violated, {used:.1}% budget used"
            );
        }
    }
    println!(
        "warm/cold      : {} warm, {} cold, {} mispredicted",
        summary.cloud_actual_warm, summary.cloud_actual_cold, summary.warm_cold_mismatches
    );
}

const HELP: &str = r#"skedge — dynamic task placement for edge-cloud serverless platforms
(reproduction of Das et al., 2020; see DESIGN.md)

USAGE:
  skedge tables  --id <experiment> [--xla]     regenerate a paper table
  skedge figures --id <fig3|fig4|fig5|fig6>    regenerate figure data (CSV)
  skedge report  [--xla]                       run every experiment
  skedge sim     --app fd --objective latency-min --set 1536,1664,2048
                 [--alpha A] [--deadline MS] [--cmax $] [--n N] [--risk R]
                 [--backend xla|native] [--generate] [--seed S]
                 [--feedback off|observe] [--record PATH|off] [--replay PATH]
  skedge fleet   --devices 1000
                 [--scenario poisson|diurnal|diurnal-tz|burst|churn|flash|
                             drift|outage]
                 [--duration-s 30] [--shards 4] [--epoch-ms 5000]
                 [--apps ir:0.4,fd:0.4,stt:0.2] [--objective latency-min]
                 [--seed S] [--rate-mult M] [--period-s P] [--amplitude A]
                 [--burst-size N] [--drift-sigma S] [--outage-frac F]
                 [--outage-period-s P] [--outage-down-s D]
                 [--feedback off|observe] [--merge per-region|global]
                 [--topology duo|triad|name:rtt[:price[:tz_s[:w]]],...]
                 [--cil private|hub] [--cross-ms 60] [--route-jitter S]
                 [--move-frac F] [--move-at-s T]
                 [--region-cap N|name:N,...] [--region-rps R|name:R,...]
                 [--throttle reject|queue[:WAIT_S]] [--failover]
                 [--outage name:START_S-END_S,...]
                 [--fabric uncapped|uplink=MBPS,access=MBPS,latency=MS]
                 [--uplink-mbps X] [--access-latency-ms Y]
                 [--record PATH|off] [--replay PATH] [--stream-metrics]
                 [--metrics PATH] [--metrics-prom PATH]
                 [--metrics-window-ms W] [--profile]

Region resilience: --region-cap / --region-rps bound each region's ground
truth (concurrent executions / admissions per second); --throttle picks what
happens past the bound (drop, or queue up to a wait deadline); --failover
retries a denied placement in the next-best surviving region (Eqn.-1 ranked,
recorded as failover hops + added routing); --outage blacks out regions for
scheduled windows; --scenario outage darkens correlated device groups.
--merge picks the epoch-barrier strategy: per-region worklist merges
(default; only contended regions pay sorting cost) or the single global
worklist — both produce bitwise-identical results and fingerprints.

Network fabric: --fabric turns on the shared-link model — each cloud
transfer crosses a private access leg (latency + serialization) and a
per-region uplink whose bandwidth is fair-shared by every transfer in
flight there, so congestion delays cloud completions and the predictor's
Eqn.-1 transfer term steers placement toward the edge when uplinks
saturate. `--fabric uncapped` (or any spec with infinite capacities and
zero latency) is bitwise identical to running without --fabric;
--uplink-mbps / --access-latency-ms override single knobs. Per-link
high-water gauges land in --metrics as `uplink_active` /
`uplink_backlog_ms` rows.
  skedge live    --app fd [--set ...] [--scale 0.05] [--runs 4]
                 [--backend xla|native] [--feedback off|observe]
                 [--record PATH] [--metrics PATH]
  skedge analyze --input PATH [--window-ms W] [--deadline MS]

`--feedback observe` closes the warm/cold loop: realized start kinds flow
back into the working CILs (sim: at response time; live: when the worker
reports; fleet: at the next epoch barrier, hubs included in --cil hub).

Observability: --record PATH writes the typed task-event stream (JSONL,
canonical (time, device, seq) order, shard-invariant); --replay PATH
re-drives arrivals (and recorded device moves) from a recorded or imported
trace — same seed + settings reproduces the original run bitwise;
--stream-metrics folds records into mergeable online summaries (exact
count/sum/min/max + quantile sketch) instead of retaining them. Recording
never changes outcomes; the printed fleet fingerprint folds in the event
count only when recording is on. --record composes with --stream-metrics:
the event stream is the full-fidelity disk spill while the in-memory side
stays O(devices + sketch).

Telemetry & analysis: --metrics PATH emits the windowed time-series
(skedge.metrics JSONL: per-window x region x app arrival/warm/denial/
latency/cost aggregates, window defaulting to the epoch length;
--metrics-window-ms overrides); --metrics-prom PATH adds a final
Prometheus-text snapshot; --profile prints the harness self-profile
(per-shard busy vs barrier-wait, scoring batch shapes, events/s).
`skedge analyze --input REC` reads any --record file offline and reports
stage attribution, the prediction audit (predicted vs realized latency and
cost, rolling error percentiles), and SLO root-cause (the first stage that
made each deadline violation unsalvageable).

Experiments: table1 table2 fig3 fig4 table3 fig5 table4 fig6 table5
             edgeonly baselines tidl configsel ablations fleet_scaling
             region_routing region_failover | all

Artifacts are read from ./artifacts (override: --artifacts DIR or
$SKEDGE_ARTIFACTS). Run `make artifacts` first.
"#;
