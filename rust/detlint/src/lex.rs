//! A minimal Rust lexer: just enough structure to walk a token stream for
//! rule matching — identifiers, punctuation, line numbers, comment capture.
//! String/char-literal and comment *content* is skipped entirely, so a rule
//! token inside a doc comment or a format string can never fire.
//!
//! This is deliberately not a parser. The offline crate registry has no
//! `syn`, so detlint makes the same hand-rolled-substrate tradeoff the main
//! crate makes for JSON/CSV/RNG: a small, dependency-free scanner whose
//! fidelity is "valid Rust in, correct token stream out". The rules it
//! feeds (see `rules.rs`) only need token-sequence matching, not syntax
//! trees.

/// One lexical token: an identifier word or a single punctuation char.
/// Numbers, literals, and comments are consumed but never emitted.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Tok {
    Ident(String),
    Punct(char),
}

/// A token tagged with its 1-based source line.
#[derive(Debug, Clone)]
pub struct Token {
    pub line: u32,
    pub tok: Tok,
}

impl Token {
    /// The identifier text, if this token is one.
    pub fn ident(&self) -> Option<&str> {
        match &self.tok {
            Tok::Ident(w) => Some(w),
            Tok::Punct(_) => None,
        }
    }

    pub fn is_punct(&self, c: char) -> bool {
        self.tok == Tok::Punct(c)
    }
}

/// Lexer output: the token stream, every `//` comment (for `detlint:`
/// directives), and the set of lines carrying at least one token — which
/// lets a comment-only line be told apart from a trailing comment when
/// deciding which line an allow directive targets.
#[derive(Debug, Default)]
pub struct Lexed {
    pub tokens: Vec<Token>,
    pub comments: Vec<(u32, String)>,
    pub code_lines: std::collections::BTreeSet<u32>,
}

/// Tokenize `src`. Assumes syntactically valid Rust; on malformed input it
/// degrades to consuming the rest of the file rather than panicking.
pub fn lex(src: &str) -> Lexed {
    let c: Vec<char> = src.chars().collect();
    let n = c.len();
    let mut out = Lexed::default();
    let mut i = 0usize;
    let mut line = 1u32;
    while i < n {
        let ch = c[i];
        if ch == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if ch.is_whitespace() {
            i += 1;
            continue;
        }
        // line comment — captured verbatim for directive parsing
        if ch == '/' && i + 1 < n && c[i + 1] == '/' {
            let start = i;
            while i < n && c[i] != '\n' {
                i += 1;
            }
            out.comments.push((line, c[start..i].iter().collect()));
            continue;
        }
        // block comment — nested, content discarded
        if ch == '/' && i + 1 < n && c[i + 1] == '*' {
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if c[i] == '\n' {
                    line += 1;
                    i += 1;
                } else if c[i] == '/' && i + 1 < n && c[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if c[i] == '*' && i + 1 < n && c[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            continue;
        }
        // identifier / keyword — or a raw/byte string prefix
        if ch.is_ascii_alphabetic() || ch == '_' {
            let start = i;
            while i < n && (c[i].is_ascii_alphanumeric() || c[i] == '_') {
                i += 1;
            }
            let word: String = c[start..i].iter().collect();
            if matches!(word.as_str(), "r" | "b" | "br" | "c" | "cr") {
                let mut j = i;
                let mut hashes = 0usize;
                while j < n && c[j] == '#' {
                    hashes += 1;
                    j += 1;
                }
                if j < n && c[j] == '"' {
                    // r"..." / b"..." / br#"..."# etc: a literal, not an ident
                    let raw = word.contains('r');
                    out.code_lines.insert(line);
                    i = j + 1;
                    skip_string(&c, &mut i, &mut line, raw, hashes);
                    continue;
                }
            }
            out.code_lines.insert(line);
            out.tokens.push(Token { line, tok: Tok::Ident(word) });
            continue;
        }
        // number literal — consumed, never emitted (method calls like
        // `1.max(2)` survive because `.` before a non-digit stops the scan)
        if ch.is_ascii_digit() {
            out.code_lines.insert(line);
            i += 1;
            while i < n && (c[i].is_ascii_alphanumeric() || c[i] == '_') {
                i += 1;
            }
            if i + 1 < n && c[i] == '.' && c[i + 1].is_ascii_digit() {
                i += 1;
                while i < n && (c[i].is_ascii_alphanumeric() || c[i] == '_') {
                    i += 1;
                }
            }
            // exponent sign: `1e-3`, `2.5E+9` (the e/E was consumed above)
            if i < n && (c[i] == '-' || c[i] == '+') && matches!(c[i - 1], 'e' | 'E') {
                i += 1;
                while i < n && c[i].is_ascii_digit() {
                    i += 1;
                }
            }
            continue;
        }
        if ch == '"' {
            out.code_lines.insert(line);
            i += 1;
            skip_string(&c, &mut i, &mut line, false, 0);
            continue;
        }
        if ch == '\'' {
            // lifetime vs char literal
            out.code_lines.insert(line);
            let next = if i + 1 < n { c[i + 1] } else { ' ' };
            if next.is_ascii_alphabetic() || next == '_' {
                let mut j = i + 1;
                while j < n && (c[j].is_ascii_alphanumeric() || c[j] == '_') {
                    j += 1;
                }
                if j == i + 2 && j < n && c[j] == '\'' {
                    i = j + 1; // 'a' — single-char literal
                } else {
                    i = j; // 'static — lifetime, consumed silently
                }
            } else if next == '\\' {
                i += 3; // quote, backslash, escaped char
                while i < n && c[i] != '\'' {
                    i += 1; // \u{...} tails
                }
                i += 1;
            } else {
                i += 2; // quote + the char itself (covers '"' and '{')
                if i < n && c[i] == '\'' {
                    i += 1;
                }
            }
            continue;
        }
        out.code_lines.insert(line);
        out.tokens.push(Token { line, tok: Tok::Punct(ch) });
        i += 1;
    }
    out
}

/// Consume string content up to (and including) the closing quote.
/// `raw` disables backslash escapes; `hashes` is the raw-string `#` count.
fn skip_string(c: &[char], i: &mut usize, line: &mut u32, raw: bool, hashes: usize) {
    let n = c.len();
    while *i < n {
        let ch = c[*i];
        if ch == '\n' {
            *line += 1;
            *i += 1;
            continue;
        }
        if !raw && ch == '\\' {
            *i += 2;
            continue;
        }
        if ch == '"' {
            let mut k = 0usize;
            while k < hashes && *i + 1 + k < n && c[*i + 1 + k] == '#' {
                k += 1;
            }
            if k == hashes {
                *i += 1 + hashes;
                return;
            }
            *i += 1;
            continue;
        }
        *i += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .tokens
            .iter()
            .filter_map(|t| t.ident().map(String::from))
            .collect()
    }

    #[test]
    fn comments_and_strings_emit_no_tokens() {
        let src = concat!(
            "// unwrap panic!\n",
            "/* partial_cmp /* nested */ */\n",
            "let s = \"Instant::now()\";\n",
        );
        assert_eq!(idents(src), vec!["let", "s"]);
    }

    #[test]
    fn raw_and_byte_strings_are_literals_not_idents() {
        let src = concat!(
            "let a = r#\"unwrap \" quote\"#;\n",
            "let b = b\"panic!\";\n",
            "let c = br##\"x\"# still\"##;\n",
        );
        assert_eq!(idents(src), vec!["let", "a", "let", "b", "let", "c"]);
    }

    #[test]
    fn char_literals_do_not_open_strings() {
        // the '"' char literal must not swallow the rest of the file
        let src = "let q = '\"'; let e = '\\''; let u = '\\u{41}'; x.unwrap();\n";
        let expect = vec!["let", "q", "let", "e", "let", "u", "x", "unwrap"];
        assert_eq!(idents(src), expect);
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let src = "fn f<'a>(x: &'a str, y: &'static u8) -> &'a str { x }\n";
        let words = idents(src);
        // lifetimes are consumed silently; the stream keeps going after them
        assert!(!words.contains(&"static".to_string()));
        assert_eq!(words.last().map(String::as_str), Some("x"));
    }

    #[test]
    fn numbers_do_not_eat_method_calls() {
        let src = "let x = 1.max(2) + 3.5e-2 + 0xFFu32; y.0.total_cmp(&z);\n";
        let words = idents(src);
        assert!(words.contains(&"max".to_string()));
        assert!(words.contains(&"total_cmp".to_string()));
    }

    #[test]
    fn line_numbers_and_code_lines() {
        let src = "let a = 1;\n// only a comment\nlet b = 2; // trailing\n";
        let lx = lex(src);
        let b_line = lx
            .tokens
            .iter()
            .find(|t| t.ident() == Some("b"))
            .map(|t| t.line);
        assert_eq!(b_line, Some(3));
        assert!(lx.code_lines.contains(&1));
        assert!(!lx.code_lines.contains(&2), "comment-only line has no code");
        assert!(lx.code_lines.contains(&3));
        assert_eq!(lx.comments.len(), 2);
        assert_eq!(lx.comments[0].0, 2);
    }
}
