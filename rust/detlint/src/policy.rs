//! The module-scoped determinism policy for the `skedge` crate: which
//! modules must be deterministic, which are allowed to read the wall
//! clock, and which are exempt from the panic-path rule.
//!
//! Paths are relative to the scanned source root (`rust/src/`), with `/`
//! separators. An entry matches a file exactly (`obs/profile.rs`,
//! `benchkit.rs`) or as a directory prefix (`fleet` matches
//! `fleet/shard.rs`).

/// Scan policy: three path lists consulted by the rules in `rules.rs`.
#[derive(Debug, Clone)]
pub struct Policy {
    /// Modules whose outputs feed fingerprints: hash-order (R1) applies
    /// only here. float-cmp (R2) and unseeded-rng (R4) apply everywhere.
    pub deterministic: Vec<String>,
    /// Modules allowed to read `Instant::now` / `SystemTime` (R3).
    pub wall_clock_ok: Vec<String>,
    /// Files exempt from the panic-path rule (R5), in addition to test
    /// code, which is always exempt.
    pub panic_exempt: Vec<String>,
}

impl Policy {
    /// The policy for this repository, mirroring the table in README.md.
    pub fn skedge() -> Policy {
        Policy {
            deterministic: owned(&[
                "fleet",
                "region",
                "fabric",
                "sim",
                "predictor",
                "platform",
                "obs",
                "engine",
            ]),
            wall_clock_ok: owned(&["live", "obs/profile.rs", "benchkit.rs"]),
            panic_exempt: owned(&["main.rs"]),
        }
    }

    /// Is `rel` inside a module that must be deterministic?
    pub fn is_deterministic(&self, rel: &str) -> bool {
        hit(&self.deterministic, rel)
    }

    /// May `rel` read the wall clock?
    pub fn wall_clock_ok(&self, rel: &str) -> bool {
        hit(&self.wall_clock_ok, rel)
    }

    /// Is `rel` exempt from the panic-path rule?
    pub fn panic_exempt(&self, rel: &str) -> bool {
        hit(&self.panic_exempt, rel)
    }
}

/// `entry` matches `rel` exactly, or as a directory prefix (`fleet` →
/// `fleet/shard.rs`).
fn hit(list: &[String], rel: &str) -> bool {
    list.iter().any(|entry| {
        if rel == entry {
            return true;
        }
        rel.len() > entry.len()
            && rel.as_bytes()[entry.len()] == b'/'
            && rel.starts_with(entry.as_str())
    })
}

fn owned(items: &[&str]) -> Vec<String> {
    items.iter().map(|s| s.to_string()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefix_matches_are_directory_scoped() {
        let p = Policy::skedge();
        assert!(p.is_deterministic("fleet/shard.rs"));
        assert!(p.is_deterministic("sim/events.rs"));
        assert!(p.is_deterministic("fabric/mod.rs"));
        assert!(!p.is_deterministic("util/json.rs"));
        // `fleet` must not match a sibling file that merely shares the prefix
        assert!(!p.is_deterministic("fleety.rs"));
    }

    #[test]
    fn wall_clock_allowlist() {
        let p = Policy::skedge();
        assert!(p.wall_clock_ok("live/mod.rs"));
        assert!(p.wall_clock_ok("obs/profile.rs"));
        assert!(p.wall_clock_ok("benchkit.rs"));
        assert!(!p.wall_clock_ok("obs/event.rs"));
        assert!(!p.wall_clock_ok("sim/mod.rs"));
    }

    #[test]
    fn panic_exemptions() {
        let p = Policy::skedge();
        assert!(p.panic_exempt("main.rs"));
        assert!(!p.panic_exempt("lib.rs"));
    }
}
