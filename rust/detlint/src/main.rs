//! CLI entry point: scan a source tree, print the report, exit nonzero on
//! any unsuppressed finding.

use std::path::PathBuf;
use std::process::ExitCode;

const HELP: &str = "\
detlint — determinism & panic-safety static analysis for skedge

USAGE:
    detlint [--root <dir>] [--quiet]

OPTIONS:
    --root <dir>   source tree to scan (default: the sibling rust/src tree)
    --quiet        print findings and the tally only, no suppression table
    -h, --help     this message

EXIT CODES:
    0   clean (suppressions and unused-allow warnings do not fail the run)
    1   at least one unsuppressed finding
    2   usage or I/O error";

fn main() -> ExitCode {
    let mut root: Option<PathBuf> = None;
    let mut quiet = false;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--root" => match args.next() {
                Some(p) => root = Some(PathBuf::from(p)),
                None => {
                    eprintln!("detlint: --root needs a directory argument");
                    return ExitCode::from(2);
                }
            },
            "--quiet" => quiet = true,
            "-h" | "--help" => {
                println!("{HELP}");
                return ExitCode::SUCCESS;
            }
            other => {
                eprintln!("detlint: unknown argument `{other}` (try --help)");
                return ExitCode::from(2);
            }
        }
    }
    let Some(root) = root.or_else(default_root) else {
        eprintln!("detlint: could not locate a source tree to scan (pass --root <dir>)");
        return ExitCode::from(2);
    };
    let policy = detlint::Policy::skedge();
    match detlint::scan_tree(&root, &policy) {
        Ok(out) => {
            let text = if quiet {
                detlint::report::render_quiet(&out)
            } else {
                detlint::report::render(&out)
            };
            print!("{text}");
            if out.findings.is_empty() {
                ExitCode::SUCCESS
            } else {
                ExitCode::FAILURE
            }
        }
        Err(e) => {
            eprintln!("detlint: scanning {}: {e}", root.display());
            ExitCode::from(2)
        }
    }
}

/// Default scan root: `../src` relative to this crate when built inside
/// the workspace, else `src` / `rust/src` under the working directory.
fn default_root() -> Option<PathBuf> {
    if let Some(manifest) = std::env::var_os("CARGO_MANIFEST_DIR") {
        let p = PathBuf::from(manifest).join("..").join("src");
        if p.is_dir() {
            return Some(p);
        }
    }
    for cand in ["src", "rust/src"] {
        let p = PathBuf::from(cand);
        if p.is_dir() {
            return Some(p);
        }
    }
    None
}
