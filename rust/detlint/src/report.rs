//! Rendering of scan results: findings as `file:line: rule — message`
//! lines (the format CI and editors key on), unused-allow warnings, a
//! one-line tally, and the suppression summary table that keeps every
//! inline allow auditable per PR.

use crate::{ScanOutcome, Suppression};

/// Render the full report. Findings come first so a failing CI log leads
/// with the actionable lines; the suppression table is printed on green
/// runs too, so allowlist drift shows up in build logs every PR.
pub fn render(out: &ScanOutcome) -> String {
    render_inner(out, true)
}

/// The `--quiet` variant: findings, warnings, and the tally, no table.
pub fn render_quiet(out: &ScanOutcome) -> String {
    render_inner(out, false)
}

fn render_inner(out: &ScanOutcome, with_table: bool) -> String {
    let mut s = String::new();
    for f in &out.findings {
        s.push_str(&format!("{}:{}: {} — {}\n", f.path, f.line, f.rule, f.message));
    }
    for w in &out.warnings {
        s.push_str(&format!("warning: {w}\n"));
    }
    s.push_str(&format!(
        "detlint: {} files scanned, {} unsuppressed finding(s), {} suppression(s), {} warning(s)\n",
        out.files,
        out.findings.len(),
        out.suppressions.len(),
        out.warnings.len(),
    ));
    if with_table && !out.suppressions.is_empty() {
        s.push_str(&render_suppressions(&out.suppressions));
    }
    s
}

/// The suppression summary table on its own — CI publishes this block as
/// the build-log audit trail.
pub fn render_suppressions(sups: &[Suppression]) -> String {
    let mut s = String::from("suppressions (inline `detlint: allow`):\n");
    let site_w = sups
        .iter()
        .map(|p| p.path.len() + digits(p.line) + 1)
        .max()
        .unwrap_or(0);
    let rule_w = sups.iter().map(|p| p.rule.len()).max().unwrap_or(0);
    for p in sups {
        let site = format!("{}:{}", p.path, p.line);
        s.push_str(&format!(
            "  {site:<site_w$}  {rule:<rule_w$}  — {reason}\n",
            rule = p.rule,
            reason = p.reason,
        ));
    }
    s
}

fn digits(mut n: u32) -> usize {
    let mut d = 1;
    while n >= 10 {
        n /= 10;
        d += 1;
    }
    d
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::Finding;

    #[test]
    fn report_lists_findings_then_tally_then_table() {
        let out = ScanOutcome {
            files: 3,
            findings: vec![Finding {
                path: "sim/mod.rs".into(),
                line: 10,
                rule: "wall-clock",
                message: "`Instant::now` outside a wall-clock module".into(),
            }],
            suppressions: vec![Suppression {
                path: "sim/events.rs".into(),
                line: 52,
                rule: "float-cmp",
                reason: "trait boilerplate".into(),
            }],
            warnings: vec!["x.rs:1: unused allow(float-cmp)".into()],
        };
        let text = render(&out);
        assert!(text.starts_with("sim/mod.rs:10: wall-clock — "));
        assert!(text.contains("warning: x.rs:1: unused allow"));
        assert!(text.contains("3 files scanned, 1 unsuppressed finding(s), 1 suppression(s)"));
        assert!(text.contains("sim/events.rs:52  float-cmp  — trait boilerplate"));
    }

    #[test]
    fn clean_run_still_prints_the_tally() {
        let out = ScanOutcome::default();
        let text = render(&out);
        assert!(text.contains("0 unsuppressed finding(s)"));
        assert!(!text.contains("suppressions ("));
    }

    #[test]
    fn digit_widths() {
        assert_eq!(digits(1), 1);
        assert_eq!(digits(9), 1);
        assert_eq!(digits(10), 2);
        assert_eq!(digits(1234), 4);
    }
}
