//! The five determinism/panic-safety rules, as token-sequence matchers
//! over the stream produced by `lex.rs`.
//!
//! | rule          | matches                                             | scope                      |
//! |---------------|-----------------------------------------------------|----------------------------|
//! | `hash-order`  | `HashMap` / `HashSet`                               | deterministic modules only |
//! | `float-cmp`   | `partial_cmp`                                       | everywhere                 |
//! | `wall-clock`  | `Instant::now`, `SystemTime`                        | outside wall-clock modules |
//! | `unseeded-rng`| `thread_rng`, `rand::random`, `OsRng`, `from_entropy` | everywhere               |
//! | `panic-path`  | `.unwrap(`, `.expect(`, `panic!`, `todo!`, `unimplemented!` | library code only  |
//!
//! `panic-path` skips test code (`#[cfg(test)]` modules, `#[test]` fns —
//! see [`test_mask`]) and the files in `Policy::panic_exempt`. The other
//! rules apply to test code too: a test that iterates a `HashMap` or reads
//! the wall clock can produce flaky assertions just as easily as library
//! code can produce flaky fingerprints.

use crate::lex::{Lexed, Tok, Token};
use crate::policy::Policy;

pub const HASH_ORDER: &str = "hash-order";
pub const FLOAT_CMP: &str = "float-cmp";
pub const WALL_CLOCK: &str = "wall-clock";
pub const UNSEEDED_RNG: &str = "unseeded-rng";
pub const PANIC_PATH: &str = "panic-path";
/// Meta-rule: a malformed `detlint:` directive is itself a finding, so a
/// reason-less allow can never silently disable enforcement.
pub const ALLOW_SYNTAX: &str = "allow-syntax";

/// The rule names an allow directive may name.
pub const SUPPRESSIBLE: [&str; 5] = [HASH_ORDER, FLOAT_CMP, WALL_CLOCK, UNSEEDED_RNG, PANIC_PATH];

/// A raw rule hit, before allow-directive processing.
#[derive(Debug, Clone)]
pub struct RawFinding {
    pub line: u32,
    pub rule: &'static str,
    pub message: String,
}

/// Run every rule over one file's token stream.
pub fn check(rel: &str, lx: &Lexed, policy: &Policy) -> Vec<RawFinding> {
    let toks = &lx.tokens;
    let tests = test_mask(toks);
    let deterministic = policy.is_deterministic(rel);
    let wall_clock_ok = policy.wall_clock_ok(rel);
    let panic_exempt = policy.panic_exempt(rel);
    let mut out = Vec::new();
    for (i, t) in toks.iter().enumerate() {
        let word = match t.ident() {
            Some(w) => w,
            None => continue,
        };
        match word {
            "HashMap" | "HashSet" if deterministic => out.push(RawFinding {
                line: t.line,
                rule: HASH_ORDER,
                message: format!(
                    "`{word}` in a deterministic module — iteration order leaks; \
                     use BTreeMap/BTreeSet or sort before iterating"
                ),
            }),
            "partial_cmp" => out.push(RawFinding {
                line: t.line,
                rule: FLOAT_CMP,
                message: "`partial_cmp` is not total on floats — use `f64::total_cmp`"
                    .to_string(),
            }),
            "Instant" if !wall_clock_ok && followed_by(toks, i, &["::", "now"]) => {
                out.push(RawFinding {
                    line: t.line,
                    rule: WALL_CLOCK,
                    message: "`Instant::now` outside a wall-clock module — \
                              use `obs::profile::Stopwatch` or virtual time"
                        .to_string(),
                })
            }
            "SystemTime" if !wall_clock_ok => out.push(RawFinding {
                line: t.line,
                rule: WALL_CLOCK,
                message: "`SystemTime` outside a wall-clock module".to_string(),
            }),
            "thread_rng" | "OsRng" | "from_entropy" => out.push(RawFinding {
                line: t.line,
                rule: UNSEEDED_RNG,
                message: format!("`{word}` is unseeded — use `util::rng` seeded streams"),
            }),
            "random" if preceded_by(toks, i, &["rand", "::"]) => out.push(RawFinding {
                line: t.line,
                rule: UNSEEDED_RNG,
                message: "`rand::random` is unseeded — use `util::rng` seeded streams"
                    .to_string(),
            }),
            "unwrap" | "expect"
                if !panic_exempt
                    && !tests[i]
                    && i > 0
                    && toks[i - 1].is_punct('.')
                    && next_is_punct(toks, i, '(') =>
            {
                out.push(RawFinding {
                    line: t.line,
                    rule: PANIC_PATH,
                    message: format!(
                        "`.{word}()` in library code — propagate the error instead"
                    ),
                })
            }
            "panic" | "todo" | "unimplemented"
                if !panic_exempt && !tests[i] && next_is_punct(toks, i, '!') =>
            {
                out.push(RawFinding {
                    line: t.line,
                    rule: PANIC_PATH,
                    message: format!("`{word}!` in library code — return an error instead"),
                })
            }
            _ => {}
        }
    }
    out
}

/// True when the tokens after `i` spell out `pattern`, where each pattern
/// element is either an ident word or a run of punctuation chars (`"::"`).
fn followed_by(toks: &[Token], i: usize, pattern: &[&str]) -> bool {
    let mut j = i + 1;
    for part in pattern {
        if part.chars().all(|c| c.is_ascii_punctuation()) {
            for ch in part.chars() {
                if j >= toks.len() || !toks[j].is_punct(ch) {
                    return false;
                }
                j += 1;
            }
        } else {
            if j >= toks.len() || toks[j].ident() != Some(part) {
                return false;
            }
            j += 1;
        }
    }
    true
}

/// True when the tokens before `i` spell out `pattern` (same element
/// grammar as [`followed_by`]), ending immediately at `i`.
fn preceded_by(toks: &[Token], i: usize, pattern: &[&str]) -> bool {
    let mut want: Vec<Tok> = Vec::new();
    for part in pattern {
        if part.chars().all(|c| c.is_ascii_punctuation()) {
            want.extend(part.chars().map(Tok::Punct));
        } else {
            want.push(Tok::Ident(part.to_string()));
        }
    }
    if i < want.len() {
        return false;
    }
    toks[i - want.len()..i]
        .iter()
        .zip(&want)
        .all(|(t, w)| &t.tok == w)
}

fn next_is_punct(toks: &[Token], i: usize, c: char) -> bool {
    toks.get(i + 1).is_some_and(|t| t.is_punct(c))
}

/// Mark every token that lives under a `test`-gated item: `#[test]` fns,
/// `#[cfg(test)]` / `#[cfg(all(test, ...))]` modules, `#[cfg_attr(test,
/// ...)]` items. The attribute's own tokens, the item header, and the full
/// brace-matched body are all marked.
pub fn test_mask(toks: &[Token]) -> Vec<bool> {
    let n = toks.len();
    let mut mask = vec![false; n];
    let mut i = 0usize;
    while i < n {
        if !toks[i].is_punct('#') {
            i += 1;
            continue;
        }
        let mut j = i + 1;
        let inner = j < n && toks[j].is_punct('!');
        if inner {
            j += 1;
        }
        if j >= n || !toks[j].is_punct('[') {
            i += 1;
            continue;
        }
        let (attr_end, has_test) = scan_attr(toks, j);
        if !has_test || inner {
            i = attr_end + 1;
            continue;
        }
        // a test-gating outer attribute: swallow any further attributes on
        // the same item, then the item through its body (or a `;` for
        // body-less items like gated `use` declarations)
        let mut m = attr_end + 1;
        while m < n && toks[m].is_punct('#') {
            let mut k = m + 1;
            if k < n && toks[k].is_punct('!') {
                k += 1;
            }
            if k < n && toks[k].is_punct('[') {
                m = scan_attr(toks, k).0 + 1;
            } else {
                break;
            }
        }
        while m < n && !toks[m].is_punct('{') && !toks[m].is_punct(';') {
            m += 1;
        }
        if m < n && toks[m].is_punct('{') {
            let mut depth = 0i32;
            while m < n {
                if toks[m].is_punct('{') {
                    depth += 1;
                } else if toks[m].is_punct('}') {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                m += 1;
            }
        }
        let end = m.min(n.saturating_sub(1));
        for slot in &mut mask[i..=end] {
            *slot = true;
        }
        i = m + 1;
    }
    mask
}

/// From the opening `[` of an attribute, find its matching `]` and report
/// whether any ident inside is exactly `test`.
fn scan_attr(toks: &[Token], open: usize) -> (usize, bool) {
    let mut depth = 0i32;
    let mut has_test = false;
    let mut k = open;
    while k < toks.len() {
        match &toks[k].tok {
            Tok::Punct('[') => depth += 1,
            Tok::Punct(']') => {
                depth -= 1;
                if depth == 0 {
                    return (k, has_test);
                }
            }
            Tok::Ident(w) if w == "test" => has_test = true,
            _ => {}
        }
        k += 1;
    }
    (toks.len().saturating_sub(1), has_test)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lex::lex;

    fn run(rel: &str, src: &str) -> Vec<RawFinding> {
        check(rel, &lex(src), &Policy::skedge())
    }

    #[test]
    fn hash_order_is_module_scoped() {
        let src = "use std::collections::HashMap;\n";
        assert_eq!(run("fleet/shard.rs", src).len(), 1);
        assert_eq!(run("fleet/shard.rs", src)[0].rule, HASH_ORDER);
        assert!(run("util/json.rs", src).is_empty());
    }

    #[test]
    fn wall_clock_needs_the_full_instant_now_path() {
        assert_eq!(run("sim/mod.rs", "let t = Instant::now();\n")[0].rule, WALL_CLOCK);
        // `Instant` as a type name alone (no `::now`) is fine
        assert!(run("sim/mod.rs", "fn f(t: Instant) {}\n").is_empty());
        assert!(run("live/mod.rs", "let t = Instant::now();\n").is_empty());
        assert_eq!(run("sim/mod.rs", "let t = SystemTime::now();\n")[0].rule, WALL_CLOCK);
    }

    #[test]
    fn rng_rule_catches_rand_random_but_not_other_randoms() {
        assert_eq!(run("util/rng.rs", "let x = rand::random::<f64>();\n")[0].rule, UNSEEDED_RNG);
        assert!(run("util/rng.rs", "let x = rng.random();\n").is_empty());
        assert_eq!(run("workload/mod.rs", "let mut r = thread_rng();\n")[0].rule, UNSEEDED_RNG);
    }

    #[test]
    fn panic_path_matchers() {
        assert_eq!(run("util/json.rs", "let v = x.unwrap();\n")[0].rule, PANIC_PATH);
        assert_eq!(run("util/json.rs", "let v = x.expect(\"msg\");\n")[0].rule, PANIC_PATH);
        assert_eq!(run("util/json.rs", "panic!(\"boom\");\n")[0].rule, PANIC_PATH);
        // `unwrap_or_else` / `expect_err`-style neighbours must not fire
        assert!(run("util/json.rs", "let v = x.unwrap_or_else(|| 0);\n").is_empty());
        assert!(run("util/json.rs", "let v = x.unwrap_or(0);\n").is_empty());
        // `main.rs` is exempt
        assert!(run("main.rs", "let v = x.unwrap();\n").is_empty());
    }

    #[test]
    fn test_code_is_exempt_from_panic_path_only() {
        let src = concat!(
            "#[cfg(test)]\n",
            "mod tests {\n",
            "    #[test]\n",
            "    fn t() { x.unwrap(); let m: HashMap<u8, u8> = HashMap::new(); }\n",
            "}\n",
        );
        let hits = run("fleet/shard.rs", src);
        // both HashMap mentions still fire; the unwrap does not
        assert_eq!(hits.len(), 2);
        assert!(hits.iter().all(|h| h.rule == HASH_ORDER));
    }

    #[test]
    fn cfg_all_test_blocks_are_test_code() {
        let src = concat!(
            "#[cfg(all(test, feature = \"xla\"))]\n",
            "mod xla_tests { fn t() { x.unwrap(); } }\n",
            "fn lib() { y.unwrap(); }\n",
        );
        let hits = run("runtime/xla.rs", src);
        assert_eq!(hits.len(), 1, "only the library-path unwrap fires");
        assert_eq!(hits[0].line, 3);
    }

    #[test]
    fn attribute_without_test_does_not_mask() {
        let src = "#[derive(Debug)]\nstruct S;\nfn f() { x.unwrap(); }\n";
        assert_eq!(run("util/json.rs", src).len(), 1);
    }
}
