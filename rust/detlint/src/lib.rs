//! detlint — determinism & panic-safety static analysis for `skedge`.
//!
//! Every claim this reproduction makes (Eqn.-1 scoring parity,
//! shard-invariant merges, bitwise record→replay round trips) rests on
//! determinism invariants that runtime tests can only spot-check. detlint
//! enforces them statically over every file under `rust/src/`:
//!
//! - `hash-order` — no `HashMap`/`HashSet` in deterministic modules
//! - `float-cmp` — no `partial_cmp`; float ordering goes through `total_cmp`
//! - `wall-clock` — no `Instant::now`/`SystemTime` outside wall-clock modules
//! - `unseeded-rng` — no `thread_rng`/`rand::random`; seeded streams only
//! - `panic-path` — no `unwrap`/`expect`/`panic!` in library code
//!
//! Intentional exceptions carry an inline reasoned directive, either
//! trailing the offending line or on a comment-only line directly above it
//! (the usual spot when the offender is a long signature):
//!
//! ```text
//! // detlint: allow(float-cmp) — trait boilerplate delegating to Ord
//! ```
//!
//! A directive without a reason, or naming an unknown rule, is itself a
//! finding (`allow-syntax`) — suppression is never free. Directives that
//! match no finding are reported as warnings so stale allows get cleaned
//! up.
//!
//! The scanner is lexer-based (`lex.rs`), not `syn`-based: the offline
//! registry has no `syn`, and token-sequence matching is enough for these
//! rules. The tradeoff is documented per-rule in `rules.rs`.

pub mod lex;
pub mod policy;
pub mod report;
pub mod rules;

pub use policy::Policy;

use std::path::{Path, PathBuf};

/// An unsuppressed rule violation.
#[derive(Debug, Clone)]
pub struct Finding {
    /// path relative to the scan root, `/`-separated
    pub path: String,
    pub line: u32,
    pub rule: &'static str,
    pub message: String,
}

/// A finding that an inline allow directive suppressed, kept for the
/// audit table.
#[derive(Debug, Clone)]
pub struct Suppression {
    pub path: String,
    pub line: u32,
    pub rule: &'static str,
    pub reason: String,
}

/// Everything one scan produced. `findings` non-empty ⇒ the tool fails.
#[derive(Debug, Default)]
pub struct ScanOutcome {
    pub files: usize,
    pub findings: Vec<Finding>,
    pub suppressions: Vec<Suppression>,
    /// non-fatal: unused allow directives
    pub warnings: Vec<String>,
}

/// A parsed `// detlint: allow(<rule>) — <reason>` directive.
#[derive(Debug)]
struct Allow {
    /// line the directive suppresses (the directive's own line if it
    /// trails code, otherwise the line below the comment-only line)
    target: u32,
    /// line the directive itself sits on (for unused-allow warnings)
    at: u32,
    rule: &'static str,
    reason: String,
    used: bool,
}

/// Scan one file's source text, appending results to `out`.
pub fn scan_source(rel: &str, src: &str, policy: &Policy, out: &mut ScanOutcome) {
    let lx = lex::lex(src);
    let raw = rules::check(rel, &lx, policy);
    let mut allows = parse_allows(rel, &lx, out);
    for f in raw {
        let slot = allows
            .iter_mut()
            .find(|a| a.target == f.line && a.rule == f.rule);
        match slot {
            Some(a) => {
                a.used = true;
                out.suppressions.push(Suppression {
                    path: rel.to_string(),
                    line: f.line,
                    rule: f.rule,
                    reason: a.reason.clone(),
                });
            }
            None => out.findings.push(Finding {
                path: rel.to_string(),
                line: f.line,
                rule: f.rule,
                message: f.message,
            }),
        }
    }
    for a in allows.iter().filter(|a| !a.used) {
        out.warnings.push(format!(
            "{rel}:{}: unused allow({}) — directive matched no finding",
            a.at, a.rule
        ));
    }
}

/// Extract allow directives from a file's comments. Malformed directives
/// become `allow-syntax` findings on the spot.
fn parse_allows(rel: &str, lx: &lex::Lexed, out: &mut ScanOutcome) -> Vec<Allow> {
    let mut allows = Vec::new();
    for (cline, text) in &lx.comments {
        let Some(pos) = text.find("detlint:") else {
            continue;
        };
        let rest = text[pos + "detlint:".len()..].trim_start();
        let Some(inner) = rest.strip_prefix("allow(") else {
            let msg = "malformed directive — expected `allow(<rule>) — <reason>`";
            bad_allow(out, rel, *cline, msg);
            continue;
        };
        let Some(close) = inner.find(')') else {
            bad_allow(out, rel, *cline, "malformed directive — missing `)` after rule name");
            continue;
        };
        let rule_name = inner[..close].trim();
        let Some(rule) = rules::SUPPRESSIBLE.iter().copied().find(|r| *r == rule_name) else {
            let msg = format!("unknown rule `{rule_name}` in allow directive");
            bad_allow(out, rel, *cline, &msg);
            continue;
        };
        let reason = inner[close + 1..]
            .trim_start()
            .trim_start_matches(&['-', '—', '–', ':'][..])
            .trim();
        if reason.is_empty() {
            bad_allow(out, rel, *cline, "allow directive without a reason — justify it");
            continue;
        }
        // a trailing comment suppresses its own line; a comment-only line
        // suppresses the line directly below it
        let target = if lx.code_lines.contains(cline) {
            *cline
        } else {
            cline + 1
        };
        allows.push(Allow {
            target,
            at: *cline,
            rule,
            reason: reason.to_string(),
            used: false,
        });
    }
    allows
}

fn bad_allow(out: &mut ScanOutcome, rel: &str, line: u32, message: &str) {
    out.findings.push(Finding {
        path: rel.to_string(),
        line,
        rule: rules::ALLOW_SYNTAX,
        message: message.to_string(),
    });
}

/// Scan every `.rs` file under `root` (sorted walk, so output order is
/// stable across platforms).
pub fn scan_tree(root: &Path, policy: &Policy) -> std::io::Result<ScanOutcome> {
    let mut files = Vec::new();
    collect_rs(root, &mut files)?;
    files.sort();
    let mut out = ScanOutcome::default();
    for f in &files {
        let src = std::fs::read_to_string(f)?;
        let rel: PathBuf = f.strip_prefix(root).unwrap_or(f).to_path_buf();
        let rel = rel
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        scan_source(&rel, &src, policy, &mut out);
        out.files += 1;
    }
    out.findings.sort_by(|a, b| (&a.path, a.line).cmp(&(&b.path, b.line)));
    out.suppressions.sort_by(|a, b| (&a.path, a.line).cmp(&(&b.path, b.line)));
    out.warnings.sort();
    Ok(out)
}

fn collect_rs(dir: &Path, files: &mut Vec<PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs(&path, files)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            files.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scan(rel: &str, src: &str) -> ScanOutcome {
        let mut out = ScanOutcome::default();
        scan_source(rel, src, &Policy::skedge(), &mut out);
        out
    }

    #[test]
    fn trailing_allow_suppresses_its_own_line() {
        let src = "let v = x.unwrap(); // detlint: allow(panic-path) — test helper seam\n";
        let out = scan("util/json.rs", src);
        assert!(out.findings.is_empty());
        assert_eq!(out.suppressions.len(), 1);
        assert_eq!(out.suppressions[0].reason, "test helper seam");
        assert!(out.warnings.is_empty());
    }

    #[test]
    fn comment_line_above_suppresses_the_next_line() {
        let src = concat!(
            "// detlint: allow(panic-path) — infallible by construction\n",
            "let v = x.unwrap();\n",
        );
        let out = scan("util/json.rs", src);
        assert!(out.findings.is_empty());
        assert_eq!(out.suppressions.len(), 1);
        assert_eq!(out.suppressions[0].line, 2, "suppression reports the code line");
    }

    #[test]
    fn allow_for_the_wrong_rule_does_not_suppress() {
        let src = "let v = x.unwrap(); // detlint: allow(float-cmp) — wrong rule\n";
        let out = scan("util/json.rs", src);
        assert_eq!(out.findings.len(), 1);
        assert_eq!(out.findings[0].rule, rules::PANIC_PATH);
        assert_eq!(out.warnings.len(), 1, "the mismatched allow is reported unused");
    }

    #[test]
    fn reasonless_allow_is_a_finding() {
        let src = "let v = x.unwrap(); // detlint: allow(panic-path)\n";
        let out = scan("util/json.rs", src);
        let rules_hit: Vec<&str> = out.findings.iter().map(|f| f.rule).collect();
        assert!(rules_hit.contains(&rules::ALLOW_SYNTAX));
        assert!(rules_hit.contains(&rules::PANIC_PATH), "violation stays unsuppressed");
    }

    #[test]
    fn unknown_rule_in_allow_is_a_finding() {
        let src = "// detlint: allow(no-such-rule) — whatever\nlet a = 1;\n";
        let out = scan("util/json.rs", src);
        assert_eq!(out.findings.len(), 1);
        assert_eq!(out.findings[0].rule, rules::ALLOW_SYNTAX);
    }

    #[test]
    fn unused_allow_warns() {
        let src = "// detlint: allow(wall-clock) — stale\nlet a = 1;\n";
        let out = scan("util/json.rs", src);
        assert!(out.findings.is_empty());
        assert_eq!(out.warnings.len(), 1);
        assert!(out.warnings[0].contains("unused allow(wall-clock)"));
    }
}
