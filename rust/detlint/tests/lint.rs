//! Integration tests: each seeded fixture triggers exactly its intended
//! rule (and nothing else), directives suppress cleanly, and — the one
//! that matters — the real `rust/src/` tree scans with zero unsuppressed
//! findings.

use detlint::{rules, scan_source, scan_tree, Policy, ScanOutcome};
use std::path::{Path, PathBuf};

fn fixture(name: &str) -> String {
    let p = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures")
        .join(name);
    match std::fs::read_to_string(&p) {
        Ok(s) => s,
        Err(e) => panic!("reading fixture {}: {e}", p.display()),
    }
}

fn scan_as(rel: &str, src: &str) -> ScanOutcome {
    let mut out = ScanOutcome::default();
    scan_source(rel, src, &Policy::skedge(), &mut out);
    out
}

/// The fixture under `rel` must produce exactly one finding, of `rule`.
fn assert_exactly(rel: &str, src: &str, rule: &str) {
    let out = scan_as(rel, src);
    let got: Vec<&str> = out.findings.iter().map(|f| f.rule).collect();
    assert_eq!(got, vec![rule], "{rel}: expected exactly one {rule} finding");
    assert!(out.suppressions.is_empty());
    assert!(out.warnings.is_empty());
}

#[test]
fn r1_fixture_fires_hash_order_in_deterministic_modules_only() {
    let src = fixture("r1_hash_order.rs");
    assert_exactly("fleet/fixture.rs", &src, rules::HASH_ORDER);
    assert_exactly("sim/fixture.rs", &src, rules::HASH_ORDER);
    // outside the deterministic set the same file is clean
    assert!(scan_as("util/fixture.rs", &src).findings.is_empty());
}

#[test]
fn r2_fixture_fires_float_cmp() {
    assert_exactly("util/fixture.rs", &fixture("r2_float_cmp.rs"), rules::FLOAT_CMP);
}

#[test]
fn r3_fixture_fires_wall_clock_outside_the_allowlist() {
    let src = fixture("r3_wall_clock.rs");
    assert_exactly("sim/fixture.rs", &src, rules::WALL_CLOCK);
    assert!(scan_as("live/fixture.rs", &src).findings.is_empty());
    assert!(scan_as("benchkit.rs", &src).findings.is_empty());
}

#[test]
fn r4_fixture_fires_unseeded_rng() {
    assert_exactly("workload/fixture.rs", &fixture("r4_unseeded_rng.rs"), rules::UNSEEDED_RNG);
}

#[test]
fn r5_fixture_fires_panic_path_except_in_exempt_files() {
    let src = fixture("r5_panic_path.rs");
    assert_exactly("util/fixture.rs", &src, rules::PANIC_PATH);
    assert!(scan_as("main.rs", &src).findings.is_empty());
}

#[test]
fn test_gated_code_is_exempt() {
    let out = scan_as("util/fixture.rs", &fixture("test_exempt.rs"));
    assert!(out.findings.is_empty(), "test-gated panics must not fire: {:?}", out.findings);
}

#[test]
fn allow_fixture_suppresses_both_directive_forms() {
    let out = scan_as("util/fixture.rs", &fixture("allow_suppressed.rs"));
    assert!(out.findings.is_empty(), "unsuppressed: {:?}", out.findings);
    assert_eq!(out.suppressions.len(), 2);
    assert!(out.suppressions.iter().all(|s| s.rule == rules::PANIC_PATH));
    assert!(out.suppressions.iter().all(|s| !s.reason.is_empty()));
    assert!(out.warnings.is_empty(), "no directive may go unused: {:?}", out.warnings);
}

/// The acceptance gate: the real source tree passes with zero
/// unsuppressed findings, and every suppression carries a reason.
#[test]
fn real_source_tree_is_clean() {
    let root: PathBuf = Path::new(env!("CARGO_MANIFEST_DIR")).join("../src");
    let out = match scan_tree(&root, &Policy::skedge()) {
        Ok(out) => out,
        Err(e) => panic!("scanning {}: {e}", root.display()),
    };
    assert!(out.files > 30, "expected the full tree, scanned {} files", out.files);
    assert!(
        out.findings.is_empty(),
        "unsuppressed findings in rust/src:\n{}",
        detlint::report::render(&out),
    );
    assert!(!out.suppressions.is_empty(), "the known allowlist should be visible");
    assert!(out.suppressions.iter().all(|s| !s.reason.is_empty()));
    assert!(
        out.warnings.is_empty(),
        "stale allow directives:\n{}",
        out.warnings.join("\n"),
    );
}
