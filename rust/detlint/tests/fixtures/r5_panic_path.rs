//! Fixture: exactly one `panic-path` violation, nothing else. (The
//! `unwrap_or` neighbour must NOT fire.)

pub fn head(xs: &[u32]) -> u32 {
    let fallback = xs.last().copied().unwrap_or(0);
    xs.first().copied().unwrap() + fallback
}
