//! Fixture: panic-path tokens live only inside test code, so the scan
//! must come back clean — `#[test]` fns and `#[cfg(test)]` / `#[cfg(all(
//! test, ...))]` modules are exempt from `panic-path`.

pub fn lib_code() -> u32 {
    7
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn t() {
        let v: Option<u32> = Some(lib_code());
        assert_eq!(v.unwrap(), 7);
    }
}

#[cfg(all(test, feature = "extra"))]
mod gated_tests {
    #[test]
    fn g() {
        let v: Option<u32> = None;
        v.expect("fine in tests");
        panic!("also fine in tests");
    }
}
