//! Fixture: exactly one `wall-clock` violation when scanned outside the
//! wall-clock allowlist, nothing else. (The `use` line mentions `Instant`
//! without `::now`, which must NOT fire.)

use std::time::Instant;

pub fn stamp() -> f64 {
    let t0 = Instant::now();
    t0.elapsed().as_secs_f64()
}
