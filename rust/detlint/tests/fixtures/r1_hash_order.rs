//! Fixture: exactly one `hash-order` violation when scanned under a
//! deterministic module path, nothing else.

use std::collections::HashMap;

pub fn build() -> usize {
    let m: std::collections::BTreeMap<u32, u32> = std::collections::BTreeMap::new();
    m.len()
}
