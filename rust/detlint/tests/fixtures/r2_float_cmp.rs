//! Fixture: exactly one `float-cmp` violation, nothing else.

pub fn sloppy_max(xs: &[f64]) -> Option<f64> {
    let mut best: Option<f64> = None;
    for x in xs {
        let better = match best {
            Some(b) => matches!(x.partial_cmp(&b), Some(std::cmp::Ordering::Greater)),
            None => true,
        };
        if better {
            best = Some(*x);
        }
    }
    best
}
