//! Fixture: exactly one `unseeded-rng` violation, nothing else. (The
//! `rng.random()` method call must NOT fire — only `rand::random` does.)

pub fn roll(rng: &mut dyn FnMut() -> u64) -> u64 {
    let seeded = rng();
    let unseeded = thread_rng();
    seeded ^ unseeded
}
