//! Fixture: one violation per directive placement form, both suppressed
//! with reasoned allow comments — zero unsuppressed findings, two
//! suppressions in the audit table.

pub fn trailing(x: Option<u32>) -> u32 {
    x.unwrap() // detlint: allow(panic-path) — fixture: trailing-form directive
}

pub fn line_above(x: Option<u32>) -> u32 {
    x
        // detlint: allow(panic-path) — fixture: line-above-form directive
        .unwrap()
}
