# Repo entry points. `make artifacts` must run before any Rust target that
# loads meta.json (sim, live, fleet, experiments, most tests).

ARTIFACTS := rust/artifacts

.PHONY: artifacts test-python clean-artifacts

artifacts:
	cd python && python3 -m compile.aot --out ../$(ARTIFACTS)

test-python:
	cd python && python3 -m pytest -q tests

clean-artifacts:
	rm -rf $(ARTIFACTS)
