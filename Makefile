# Repo entry points. `make artifacts` must run before any Rust target that
# loads meta.json (sim, live, fleet, experiments, most tests).

ARTIFACTS := rust/artifacts

.PHONY: artifacts test-python clean-artifacts verify soak record-replay analyze-demo lint alloc-check merge-smoke fabric-smoke

artifacts:
	cd python && python3 -m compile.aot --out ../$(ARTIFACTS)

# Tier-1 verification: release build + the full test suite, which already
# includes the cross-path invariant suites under rust/tests/ (fleet shard
# determinism, region topology, one-scoring-core pins, live parity +
# closed-loop feedback, region resilience + property suites). Assumes
# `make artifacts` has run.
verify:
	cd rust && cargo build --release && cargo test -q

# Static determinism & panic-safety pass (rust/detlint) plus clippy's
# disallowed-methods layer (rust/clippy.toml). detlint prints the
# suppression summary table on green runs too, so the inline allowlist
# stays visible; any unsuppressed finding fails the target. Needs no
# artifacts — it only reads source.
lint:
	cd rust && cargo run --release -p detlint
	cd rust && cargo clippy --all-targets -- -D warnings
	cd rust && cargo clippy -p detlint --all-targets -- -D warnings

# Allocation-regression pin: drives a shard's epoch loop directly under a
# counting global allocator and fails if any steady-state epoch (after
# prewarm + warmup) performs a single heap allocation. Release mode so
# the measured path is the one the benchmarks run. Assumes
# `make artifacts` has run.
alloc-check:
	cd rust && cargo test --release --test alloc -- --nocapture

# Per-region vs global epoch-barrier merge fingerprint smoke through the
# CLI: the same 2-shard fleet under both --merge strategies must print
# identical fingerprints (the bitwise-equivalence guarantee end to end;
# the in-process pins live in rust/tests/fleet.rs and resilience.rs).
# Assumes `make artifacts` has run.
merge-smoke:
	cd rust && cargo run --release --quiet -- fleet --devices 12 --duration-s 6 \
		--scenario poisson --shards 2 --topology duo --merge per-region \
		| tee /tmp/skedge-merge-pr.out
	cd rust && cargo run --release --quiet -- fleet --devices 12 --duration-s 6 \
		--scenario poisson --shards 2 --topology duo --merge global \
		| tee /tmp/skedge-merge-global.out
	@a=$$(grep '^fingerprint' /tmp/skedge-merge-pr.out); \
	b=$$(grep '^fingerprint' /tmp/skedge-merge-global.out); \
	if [ "$$a" = "$$b" ]; then echo "merge-smoke: strategies agree ($$a)"; \
	else echo "merge-smoke: MISMATCH: per-region '$$a' vs global '$$b'" >&2; exit 1; fi

# Network-fabric smoke through the CLI: the same flash-crowd fleet run
# three ways. `--fabric uncapped` must print the identical fingerprint to
# no --fabric at all (the bitwise-identity guarantee end to end), while a
# capped uplink must print a *different* one (congestion visibly changes
# the run). The in-process pins live in rust/tests/network.rs. Assumes
# `make artifacts` has run.
fabric-smoke:
	cd rust && cargo run --release --quiet -- fleet --devices 12 --duration-s 16 \
		--scenario flash --shards 2 --topology duo \
		| tee /tmp/skedge-fabric-off.out
	cd rust && cargo run --release --quiet -- fleet --devices 12 --duration-s 16 \
		--scenario flash --shards 2 --topology duo --fabric uncapped \
		| tee /tmp/skedge-fabric-free.out
	cd rust && cargo run --release --quiet -- fleet --devices 12 --duration-s 16 \
		--scenario flash --shards 2 --topology duo --fabric uplink=4,latency=2 \
		| tee /tmp/skedge-fabric-capped.out
	@off=$$(grep '^fingerprint' /tmp/skedge-fabric-off.out); \
	free=$$(grep '^fingerprint' /tmp/skedge-fabric-free.out); \
	cap=$$(grep '^fingerprint' /tmp/skedge-fabric-capped.out); \
	if [ "$$off" != "$$free" ]; then \
		echo "fabric-smoke: MISMATCH: uncapped fabric '$$free' != off '$$off'" >&2; exit 1; fi; \
	if [ "$$off" = "$$cap" ]; then \
		echo "fabric-smoke: capped uplink did not change the run ($$cap)" >&2; exit 1; fi; \
	echo "fabric-smoke: uncapped is identity ($$off), capped diverges ($$cap)"

# Long-soak nondeterminism smoke: the 10-epoch outage storm (caps + rate
# limits + queueing + failover + region blackouts + correlated device
# outages) replayed across shard counts and epoch lengths. #[ignore]d by
# default; this target opts in.
soak:
	cd rust && cargo test --release --test resilience -- --ignored --nocapture

# Record → replay round-trip through the CLI: record a small fleet's event
# stream, re-drive the identical fleet from its own recording (--replay
# accepts the recorded events file directly), and require the two printed
# fingerprints to match — the bitwise-reproduction guarantee end to end.
# Assumes `make artifacts` has run.
record-replay:
	cd rust && cargo run --release --quiet -- fleet --devices 8 --duration-s 6 \
		--scenario poisson --record /tmp/skedge-record.jsonl | tee /tmp/skedge-record.out
	cd rust && cargo run --release --quiet -- fleet --devices 8 --duration-s 6 \
		--replay /tmp/skedge-record.jsonl --record /tmp/skedge-replay.jsonl | tee /tmp/skedge-replay.out
	@a=$$(grep '^fingerprint' /tmp/skedge-record.out); \
	b=$$(grep '^fingerprint' /tmp/skedge-replay.out); \
	if [ "$$a" = "$$b" ]; then echo "record-replay: round trip reproduced ($$a)"; \
	else echo "record-replay: MISMATCH: recorded '$$a' vs replayed '$$b'" >&2; exit 1; fi

# Record → analyze loop through the CLI: record a small fleet's event
# stream (plus its windowed metrics series), run the offline analyzer on
# the recording, and require a non-empty prediction audit — every decision
# paired with its completion. Assumes `make artifacts` has run.
analyze-demo:
	cd rust && cargo run --release --quiet -- fleet --devices 8 --duration-s 6 \
		--scenario poisson --record /tmp/skedge-analyze.jsonl \
		--metrics /tmp/skedge-metrics.jsonl
	cd rust && cargo run --release --quiet -- analyze --input /tmp/skedge-analyze.jsonl \
		| tee /tmp/skedge-analyze.out
	@n=$$(sed -n 's/^audited decisions: //p' /tmp/skedge-analyze.out); \
	if [ -n "$$n" ] && [ "$$n" -gt 0 ]; then echo "analyze-demo: audited $$n decisions"; \
	else echo "analyze-demo: empty prediction audit" >&2; exit 1; fi

test-python:
	cd python && python3 -m pytest -q tests

clean-artifacts:
	rm -rf $(ARTIFACTS)
