# Repo entry points. `make artifacts` must run before any Rust target that
# loads meta.json (sim, live, fleet, experiments, most tests).

ARTIFACTS := rust/artifacts

.PHONY: artifacts test-python clean-artifacts verify soak

artifacts:
	cd python && python3 -m compile.aot --out ../$(ARTIFACTS)

# Tier-1 verification: release build + the full test suite, which already
# includes the cross-path invariant suites under rust/tests/ (fleet shard
# determinism, region topology, one-scoring-core pins, live parity +
# closed-loop feedback, region resilience + property suites). Assumes
# `make artifacts` has run.
verify:
	cd rust && cargo build --release && cargo test -q

# Long-soak nondeterminism smoke: the 10-epoch outage storm (caps + rate
# limits + queueing + failover + region blackouts + correlated device
# outages) replayed across shard counts and epoch lengths. #[ignore]d by
# default; this target opts in.
soak:
	cd rust && cargo test --release --test resilience -- --ignored --nocapture

test-python:
	cd python && python3 -m pytest -q tests

clean-artifacts:
	rm -rf $(ARTIFACTS)
