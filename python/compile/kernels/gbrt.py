"""Pallas kernel: GBRT forest evaluation (the L1 compute hot-spot).

The Predictor must score every input against all 19 cloud container
configurations: a [B, F] feature block (input size, container memory) is
pushed through T depth-D regression trees.

Formulation — gather-free select-tree, all trees at once:

  * node feature values are materialized with per-feature masks:
    ``xv[b,t,n] = select(feat[t,n] == f, x[b,f], ...)`` (F is tiny);
  * one vectorized compare produces all node decisions ``cmp [Bb, T, NI]``;
  * the descent is a *static* select-tree: node indices are Python-level
    constants, so each level is a static slice + lane-wise select over
    [Bb, T] planes — 2^D − 1 selects total, no dynamic gather anywhere;
  * leaf values are static column slices of the leaf table (no leaf
    gather), and trees reduce with one sum over the T axis. (Equivalently
    a one-hot × leaf contraction — MXU-shaped if a real TPU wants it.)

This matters twice: XLA 0.5.1's CPU backend lowers dynamic gathers and
rolled while-loops poorly (the original fori_loop-over-trees kernel paid
per-iteration dispatch), and on TPU the select-tree is pure lane-parallel
VPU work with no serialization. Measured effect on the Rust request path:
see EXPERIMENTS.md §Perf.

Layout/TPU mapping: the batch is tiled over the grid (`block_b` rows per
step); tree tables are replicated to every grid step via constant
BlockSpec index maps (they are compile-time constants in the surrounding
graph, ≈ 9 KB); the per-step VMEM working set is the [Bb, T, NI] compare
plane (block 32: 32·100·7·4 B ≈ 90 KB — comfortably inside the ~16 MB VMEM
budget; 32 was chosen by a block-size sweep on the CPU request path,
see EXPERIMENTS.md §Perf).

`interpret=True` always: the CPU PJRT client cannot execute Mosaic
custom-calls, and this repo's AOT path (HLO text → Rust) runs on CPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _forest_kernel(x_ref, fi_ref, th_ref, lf_ref, o_ref, *, n_feat: int,
                   depth: int, base: float, learning_rate: float):
    x = x_ref[...]                      # [Bb, F] f32
    fi = fi_ref[...]                    # [T, NI] i32
    th = th_ref[...]                    # [T, NI] f32
    lf = lf_ref[...]                    # [T, NL] f32
    bb = x.shape[0]
    n_trees, n_internal = fi.shape

    # xv[b, t, n] = x[b, fi[t, n]] via per-feature masks — no gather
    xv = jnp.zeros((bb, n_trees, n_internal), jnp.float32)
    for f in range(n_feat):
        xv = jnp.where((fi == f)[None, :, :], x[:, f][:, None, None], xv)
    cmp = xv >= th[None, :, :]          # [Bb, T, NI] node decisions

    # static select-tree descent: value(node) = [Bb, T] plane of leaf
    # values reachable from `node`; node indices are Python constants
    def value(node: int):
        if node >= n_internal:          # leaf column, static slice
            col = lf[:, node - n_internal]
            return jnp.broadcast_to(col[None, :], (bb, n_trees))
        return jnp.where(cmp[:, :, node], value(2 * node + 2),
                         value(2 * node + 1))

    acc = value(0).sum(axis=1)          # [Bb]
    o_ref[...] = jnp.float32(base) + jnp.float32(learning_rate) * acc


def forest_eval(x, feat, thresh, leaf, *, base: float, learning_rate: float,
                block_b: int = 32):
    """Evaluate a dense GBRT forest with the Pallas kernel.

    x: [B, F] float32; feat/thresh: [T, 2^D - 1]; leaf: [T, 2^D].
    Returns [B] float32. B is padded up to a multiple of `block_b`
    internally; callers see the exact size back.
    """
    x = jnp.asarray(x, jnp.float32)
    feat = jnp.asarray(feat, jnp.int32)
    thresh = jnp.asarray(thresh, jnp.float32)
    leaf = jnp.asarray(leaf, jnp.float32)

    b, f_dim = x.shape
    n_trees, n_internal = feat.shape
    depth = int(n_internal + 1).bit_length() - 1
    assert 2 ** depth - 1 == n_internal, "internal node count must be 2^D - 1"
    assert leaf.shape == (n_trees, 2 ** depth)

    bb = min(block_b, max(b, 1))
    b_pad = ((b + bb - 1) // bb) * bb
    if b_pad != b:
        x = jnp.pad(x, ((0, b_pad - b), (0, 0)))
    grid = (b_pad // bb,)

    kernel = functools.partial(_forest_kernel, n_feat=f_dim, depth=depth,
                               base=base, learning_rate=learning_rate)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, f_dim), lambda i: (i, 0)),
            pl.BlockSpec((n_trees, n_internal), lambda i: (0, 0)),
            pl.BlockSpec((n_trees, n_internal), lambda i: (0, 0)),
            pl.BlockSpec((n_trees, 2 ** depth), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bb,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b_pad,), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls (see module doc)
    )(x, feat, thresh, leaf)
    return out[:b]
