"""Pure-jnp oracle for the GBRT forest-evaluation kernel.

Same dense complete-binary-tree layout as ``training.GbrtForest``:
  feat   [T, 2^D - 1] int32
  thresh [T, 2^D - 1] float32   (descend right iff x[f] >= t; +inf = always left)
  leaf   [T, 2^D]     float32
Prediction = base + lr * sum_t leaf_t(descend(x)).
"""

from __future__ import annotations

import jax.numpy as jnp


def forest_eval_ref(x, feat, thresh, leaf, *, base, learning_rate):
    """Evaluate the forest. x: [B, F] -> [B] (float32).

    Vectorized level-by-level descent over all trees at once; the
    correctness oracle for the Pallas kernel and the Rust-native mirror.
    """
    x = jnp.asarray(x, jnp.float32)
    feat = jnp.asarray(feat, jnp.int32)
    thresh = jnp.asarray(thresh, jnp.float32)
    leaf = jnp.asarray(leaf, jnp.float32)

    n_trees, n_internal = feat.shape
    depth = int(n_internal + 1).bit_length() - 1  # 2^D - 1 internal -> D levels
    assert 2 ** depth - 1 == n_internal, "internal node count must be 2^D - 1"
    b = x.shape[0]

    # idx[B, T]: current internal-node index per (sample, tree)
    idx = jnp.zeros((b, n_trees), jnp.int32)
    feat_bt = jnp.broadcast_to(feat[None, :, :], (b, n_trees, n_internal))
    thr_bt = jnp.broadcast_to(thresh[None, :, :], (b, n_trees, n_internal))
    for _ in range(depth):
        f = jnp.take_along_axis(feat_bt, idx[:, :, None], axis=2)[..., 0]  # [B,T]
        t = jnp.take_along_axis(thr_bt, idx[:, :, None], axis=2)[..., 0]   # [B,T]
        xv = jnp.take_along_axis(x, f.reshape(b, -1), axis=1).reshape(b, n_trees)
        idx = 2 * idx + 1 + (xv >= t).astype(jnp.int32)
    leaf_idx = idx - n_internal                                            # [B,T]
    n_leaf = leaf.shape[1]
    leaf_bt = jnp.broadcast_to(leaf[None, :, :], (b, n_trees, n_leaf))
    vals = jnp.take_along_axis(leaf_bt, leaf_idx[:, :, None], axis=2)[..., 0]
    return jnp.float32(base) + jnp.float32(learning_rate) * vals.sum(axis=1)
