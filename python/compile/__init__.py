"""AOT compilation pipeline: synthetic ground truth, trained performance
models, and the JAX/Pallas prediction graph lowered to HLO artifacts."""
