"""Model training for the paper's performance models — no sklearn available,
so the estimators are implemented here in numpy:

  * ``fit_ols`` / ``fit_ridge`` — linear models for upld(k) and comp_e(k),
  * ``GbrtForest`` — gradient-boosted regression trees (squared loss, exact
    greedy splits over quantile-binned thresholds) for comp(k, m), matching
    the paper's choice of Gradient Boosted Regression Trees [Friedman 2002].

The trained forest is exported as three dense arrays (complete binary trees):

  feat   [T, 2^D - 1] int32   feature index tested at each internal node
  thresh [T, 2^D - 1] float32 split threshold (go right if x[f] >= t)
  leaf   [T, 2^D]     float32 leaf values

Dead internal nodes (below a leaf-ified ancestor) carry feature 0 and
threshold +inf, so descent always goes left and lands on the ancestor's value,
which is replicated down to the corresponding leaves.  This dense layout is
what the Pallas kernel (L1) and the Rust-native mirror consume.
"""

from __future__ import annotations

import dataclasses

import numpy as np


# ---------------------------------------------------------------- linear ----

def fit_ols(x: np.ndarray, y: np.ndarray) -> tuple[float, float]:
    """Least-squares fit y ~ b0 + b1*x. Returns (b0, b1)."""
    x = np.asarray(x, dtype=np.float64)
    y = np.asarray(y, dtype=np.float64)
    xm, ym = x.mean(), y.mean()
    vx = ((x - xm) ** 2).sum()
    b1 = ((x - xm) * (y - ym)).sum() / max(vx, 1e-12)
    return float(ym - b1 * xm), float(b1)


def fit_ridge(x: np.ndarray, y: np.ndarray, lam: float = 1.0) -> tuple[float, float]:
    """Ridge fit y ~ b0 + b1*x with standardized x (penalty on slope only)."""
    x = np.asarray(x, dtype=np.float64)
    y = np.asarray(y, dtype=np.float64)
    xm, ym = x.mean(), y.mean()
    sx = x.std() + 1e-12
    xs = (x - xm) / sx
    b1s = (xs * (y - ym)).sum() / (float((xs ** 2).sum()) + lam)
    b1 = b1s / sx
    return float(ym - b1 * xm), float(b1)


# ------------------------------------------------------------------ GBRT ----

@dataclasses.dataclass
class GbrtForest:
    """Dense complete-binary-tree forest. Arrays as described in the module doc."""

    base: float                 # initial prediction (mean of y)
    learning_rate: float
    feat: np.ndarray            # [T, 2^D - 1] int32
    thresh: np.ndarray          # [T, 2^D - 1] float32
    leaf: np.ndarray            # [T, 2^D]     float32

    @property
    def n_trees(self) -> int:
        return self.feat.shape[0]

    @property
    def depth(self) -> int:
        return int(np.log2(self.leaf.shape[1]))

    def predict(self, x: np.ndarray) -> np.ndarray:
        """Reference inference, [B, F] -> [B]. The oracle for L1/Rust."""
        x = np.asarray(x, dtype=np.float64)
        n_internal = self.feat.shape[1]
        out = np.full(x.shape[0], self.base, dtype=np.float64)
        for t in range(self.n_trees):
            idx = np.zeros(x.shape[0], dtype=np.int64)
            for _ in range(self.depth):
                f = self.feat[t, idx]
                thr = self.thresh[t, idx]
                go_right = x[np.arange(x.shape[0]), f] >= thr
                idx = 2 * idx + 1 + go_right.astype(np.int64)
            out += self.learning_rate * self.leaf[t, idx - n_internal]
        return out

    def to_flat(self) -> dict:
        """JSON-friendly export consumed by meta.json / Rust."""
        return {
            "base": self.base,
            "learning_rate": self.learning_rate,
            "n_trees": int(self.n_trees),
            "depth": int(self.depth),
            "feat": self.feat.astype(int).ravel().tolist(),
            # +inf marks dead branches; JSON has no Infinity, so export a
            # finite f32 sentinel far above any real feature value.
            "thresh": [float(v) if np.isfinite(v) else 3.0e38
                       for v in self.thresh.ravel()],
            "leaf": [float(v) for v in self.leaf.ravel()],
        }


def _best_split(x: np.ndarray, g: np.ndarray, feature_bins: list[np.ndarray],
                min_leaf: int):
    """Exact greedy split of residuals g over candidate thresholds.

    Returns (gain, feature, threshold) or None. Split criterion is variance
    reduction (equivalently squared-loss gain).
    """
    n = x.shape[0]
    if n < 2 * min_leaf:
        return None
    best = None
    total_sum = g.sum()
    total_cnt = n
    base_score = total_sum * total_sum / total_cnt
    for f, bins in enumerate(feature_bins):
        xf = x[:, f]
        order = np.argsort(xf, kind="stable")
        xs, gs = xf[order], g[order]
        csum = np.cumsum(gs)
        # candidate split positions: where threshold separates xs[i-1] < t <= xs[i]
        for t in bins:
            i = np.searchsorted(xs, t, side="left")
            if i < min_leaf or total_cnt - i < min_leaf:
                continue
            left_sum = csum[i - 1]
            right_sum = total_sum - left_sum
            score = left_sum * left_sum / i + right_sum * right_sum / (total_cnt - i)
            gain = score - base_score
            if gain > 1e-9 and (best is None or gain > best[0]):
                best = (gain, f, float(t))
    return best


def _fit_tree(x: np.ndarray, g: np.ndarray, depth: int, min_leaf: int,
              n_bins: int, rng: np.random.Generator):
    """Fit one dense regression tree of exactly `depth` levels on residuals g."""
    n_internal = 2 ** depth - 1
    n_leaf = 2 ** depth
    feat = np.zeros(n_internal, dtype=np.int32)
    thresh = np.full(n_internal, np.inf, dtype=np.float32)  # dead node: always left
    leaf = np.zeros(n_leaf, dtype=np.float32)

    # Quantile bins per feature, computed once on this tree's sample.
    feature_bins = []
    for f in range(x.shape[1]):
        qs = np.unique(np.quantile(x[:, f], np.linspace(0.02, 0.98, n_bins)))
        feature_bins.append(qs)

    # node -> boolean mask of samples reaching it
    masks = {0: np.ones(x.shape[0], dtype=bool)}
    values = {0: float(g.mean()) if x.shape[0] else 0.0}
    for node in range(n_internal):
        mask = masks.get(node)
        if mask is None or not mask.any():
            # Dead branch: keep +inf threshold, propagate ancestor value.
            for child in (2 * node + 1, 2 * node + 2):
                if child < n_internal:
                    masks[child] = None
                    values[child] = values.get(node, 0.0)
            continue
        xm, gm = x[mask], g[mask]
        values[node] = float(gm.mean())
        split = _best_split(xm, gm, feature_bins, min_leaf)
        if split is None:
            feat[node] = 0
            thresh[node] = np.inf  # everything goes left; right side dead
            left = mask
            right = np.zeros_like(mask)
        else:
            _, f, t = split
            feat[node] = f
            thresh[node] = t
            go_right = x[:, f] >= t
            left = mask & ~go_right
            right = mask & go_right
        for child, cmask in ((2 * node + 1, left), (2 * node + 2, right)):
            if child < n_internal:
                masks[child] = cmask if cmask.any() else None
                values[child] = float(g[cmask].mean()) if cmask.any() else values[node]

    # Leaves: children of the last internal level.
    first_leaf_parent = (n_internal - 1) // 2
    for parent in range(first_leaf_parent, n_internal):
        pmask = masks.get(parent)
        pval = values.get(parent, 0.0)
        f, t = feat[parent], thresh[parent]
        li = 2 * parent + 1 - n_internal
        ri = 2 * parent + 2 - n_internal
        if pmask is None or not pmask.any():
            leaf[li] = pval
            leaf[ri] = pval
            continue
        go_right = (x[:, f] >= t) & pmask
        go_left = pmask & ~go_right
        leaf[li] = float(g[go_left].mean()) if go_left.any() else pval
        leaf[ri] = float(g[go_right].mean()) if go_right.any() else pval
    return feat, thresh, leaf


def fit_gbrt(x: np.ndarray, y: np.ndarray, *, n_trees: int = 100, depth: int = 3,
             learning_rate: float = 0.1, subsample: float = 0.9,
             min_leaf: int = 8, n_bins: int = 32,
             seed: int = 0) -> GbrtForest:
    """Gradient boosting with squared loss: each tree fits the residuals."""
    x = np.asarray(x, dtype=np.float64)
    y = np.asarray(y, dtype=np.float64)
    rng = np.random.default_rng(seed)
    base = float(y.mean())
    pred = np.full_like(y, base)
    feats, threshs, leaves = [], [], []
    n = x.shape[0]
    for _ in range(n_trees):
        g = y - pred
        if subsample < 1.0:
            sel = rng.random(n) < subsample
            if sel.sum() < 4 * min_leaf:
                sel = np.ones(n, dtype=bool)
        else:
            sel = np.ones(n, dtype=bool)
        f, t, l = _fit_tree(x[sel], g[sel], depth, min_leaf, n_bins, rng)
        feats.append(f)
        threshs.append(t)
        leaves.append(l)
        # update predictions on the FULL set with the new tree
        tree = GbrtForest(0.0, 1.0, f[None, :], t[None, :], l[None, :])
        pred = pred + learning_rate * tree.predict(x)
    return GbrtForest(base, learning_rate,
                      np.stack(feats), np.stack(threshs), np.stack(leaves))


# --------------------------------------------------------------- metrics ----

def mape(y_true: np.ndarray, y_pred: np.ndarray) -> float:
    y_true = np.asarray(y_true, dtype=np.float64)
    y_pred = np.asarray(y_pred, dtype=np.float64)
    denom = np.maximum(np.abs(y_true), 1e-9)
    return float(np.mean(np.abs(y_true - y_pred) / denom) * 100.0)
