"""L2: the paper's performance-prediction graph in JAX.

For one application, ``make_predict_fn`` builds

    predict(sizes [B]) -> (upld [B], comp_cloud [B, 19], comp_edge [B],
                           cost_cloud [B, 19])

where
  * ``upld``       — linear upload-time model  theta0 + theta1 * bytes(k),
  * ``comp_cloud`` — GBRT forest over (size, memory) via the L1 Pallas kernel,
    one column per cloud container configuration,
  * ``comp_edge``  — ridge linear model  phi0 + phi1 * size(k),
  * ``cost_cloud`` — in-graph AWS billing: ceil(comp / 100 ms) GB-s price
    plus the per-request fee.

Scalar components (warm/cold start means, store, iotup) stay on the Rust
side: the CIL decides warm-vs-cold per request, so they are added by the
coordinator when assembling Eqn. (1)/(2).

All trained parameters are baked into the graph as constants at lowering
time; the AOT artifact is self-contained per application.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from . import synthdata
from .kernels import gbrt
from .training import GbrtForest


@dataclasses.dataclass
class TrainedModels:
    """Everything the Predictor needs for one application."""

    app: str
    theta: tuple[float, float]        # upld ~ theta0 + theta1 * bytes
    phi: tuple[float, float]          # comp_e ~ phi0 + phi1 * size
    forest: GbrtForest                # comp(k, m), features = (size, mem MB)
    bytes_per_unit: float
    # scalar component means (ms) — consumed by Rust, also kept here for eval
    start_warm_mean: float
    start_cold_mean: float
    store_mean: float
    iotup_mean: float                 # <0 -> n/a (IR)
    edge_store_mean: float

    def edge_overhead_ms(self) -> float:
        iot = self.iotup_mean if self.iotup_mean >= 0 else 0.0
        return iot + self.edge_store_mean

    def predict_cloud_e2e_warm(self, sizes: np.ndarray) -> np.ndarray:
        """[B] -> [B, 19] warm end-to-end prediction (numpy, for evaluation)."""
        sizes = np.asarray(sizes, dtype=np.float64)
        byts = sizes * self.bytes_per_unit
        upld = self.theta[0] + self.theta[1] * byts
        mems = np.asarray(synthdata.MEMORY_CONFIGS_MB, dtype=np.float64)
        feats = np.stack([
            np.repeat(sizes, len(mems)),
            np.tile(mems, len(sizes)),
        ], axis=1)
        comp = self.forest.predict(feats).reshape(len(sizes), len(mems))
        comp = np.maximum(comp, 1.0)
        return upld[:, None] + self.start_warm_mean + comp + self.store_mean

    def predict_edge_e2e(self, sizes: np.ndarray) -> np.ndarray:
        sizes = np.asarray(sizes, dtype=np.float64)
        comp_e = np.maximum(self.phi[0] + self.phi[1] * sizes, 1.0)
        return comp_e + self.edge_overhead_ms()


def make_predict_fn(models: TrainedModels, block_b: int = 32):
    """Build the jittable predict function with parameters baked as constants."""
    mems = jnp.asarray(synthdata.MEMORY_CONFIGS_MB, jnp.float32)       # [N]
    n_cfg = mems.shape[0]
    theta0, theta1 = (jnp.float32(v) for v in models.theta)
    phi0, phi1 = (jnp.float32(v) for v in models.phi)
    bpu = jnp.float32(models.bytes_per_unit)
    feat = jnp.asarray(models.forest.feat, jnp.int32)
    thresh = jnp.asarray(models.forest.thresh, jnp.float32)
    leaf = jnp.asarray(models.forest.leaf, jnp.float32)
    base = float(models.forest.base)
    lr = float(models.forest.learning_rate)

    price = jnp.float32(synthdata.PRICE_PER_GB_S)
    quantum = jnp.float32(synthdata.BILL_QUANTUM_MS)
    fee = jnp.float32(synthdata.REQUEST_FEE)
    mem_gb = mems / jnp.float32(1024.0)                                 # [N]

    def predict(sizes):
        sizes = jnp.asarray(sizes, jnp.float32)                         # [B]
        b = sizes.shape[0]
        upld = theta0 + theta1 * (sizes * bpu)                          # [B]
        # feature grid [B*N, 2]: (size, mem)
        size_col = jnp.repeat(sizes, n_cfg)
        mem_col = jnp.tile(mems, b)
        feats = jnp.stack([size_col, mem_col], axis=1)
        comp = gbrt.forest_eval(feats, feat, thresh, leaf, base=base,
                                learning_rate=lr, block_b=block_b)
        comp = jnp.maximum(comp.reshape(b, n_cfg), 1.0)                 # [B, N]
        comp_edge = jnp.maximum(phi0 + phi1 * sizes, 1.0)               # [B]
        billed_s = jnp.ceil(comp / quantum) * (quantum / jnp.float32(1e3))
        cost = price * mem_gb[None, :] * billed_s + fee                 # [B, N]
        return (upld, comp, comp_edge, cost)

    return predict
