"""AOT pipeline: train the performance models, lower the per-app prediction
graph to HLO text, and emit everything the Rust coordinator consumes.

Outputs (under ``artifacts/``):

  {app}_b{B}.hlo.txt   per-app predictor at batch sizes B in {1, 64}.
                       HLO *text*, not a serialized HloModuleProto: jax >= 0.5
                       emits 64-bit instruction ids that xla_extension 0.5.1
                       rejects; the text parser reassigns ids cleanly.
  meta.json            memory configs, pricing constants, component means and
                       sigmas, T_idl, trained model parameters (for the
                       Rust-native mirror backend), ground-truth parameters
                       (for the Rust generative workload path), Table II
                       metrics, and per-app experiment constants (delta,
                       C_max, alpha, arrival rates).
  {app}_eval.csv       600-input replay tables of *actual* component
                       latencies, mirroring the paper's simulation protocol
                       ("we simulate execution using the actual end-to-end
                       latency and actual costs from the measured data").

Run once via ``make artifacts``; Python never runs on the request path.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from . import synthdata
from .model import TrainedModels, make_predict_fn
from .training import fit_gbrt, fit_ols, fit_ridge, mape

BATCH_SIZES = (1, 64)
TRAIN_SEED = 2020           # publication year; fixed for reproducibility
EVAL_SEED = 7_102_026


def train_app(app: synthdata.AppGroundTruth, seed: int = TRAIN_SEED):
    """Collect a synthetic training set and fit all component models."""
    rng = np.random.default_rng(seed)
    ds = synthdata.sample_dataset(app, app.n_train, rng)
    train, test = synthdata.train_test_split(ds, 0.8, rng)

    theta = fit_ols(train["bytes"], train["upld"])
    phi = fit_ridge(train["size"], train["edge_comp"], lam=1.0)

    mems = np.asarray(synthdata.MEMORY_CONFIGS_MB, dtype=np.float64)
    n_tr = len(train["size"])
    feats = np.stack([
        np.repeat(train["size"], len(mems)),
        np.tile(mems, n_tr),
    ], axis=1)
    targets = train["comp"].ravel()
    forest = fit_gbrt(feats, targets, n_trees=100, depth=3, learning_rate=0.1,
                      subsample=0.9, min_leaf=16, n_bins=32, seed=seed)

    models = TrainedModels(
        app=app.name,
        theta=theta,
        phi=phi,
        forest=forest,
        bytes_per_unit=app.bytes_per_unit,
        start_warm_mean=float(train["start_w"].mean()),
        start_cold_mean=float(train["start_c"].mean()),
        store_mean=float(train["store"].mean()),
        iotup_mean=float(train["iotup"].mean()) if app.iotup_mean >= 0 else -1.0,
        edge_store_mean=float(train["edge_store"].mean()),
    )
    return models, train, test


def evaluate(models: TrainedModels, test: dict) -> dict:
    """Table II: MAPE of end-to-end latency predictions on the test split."""
    pred_cloud = models.predict_cloud_e2e_warm(test["size"])      # [B, 19]
    actual_cloud = synthdata.e2e_cloud_warm(test)
    pred_edge = models.predict_edge_e2e(test["size"])
    actual_edge = synthdata.e2e_edge(test)
    return {
        "mape_cloud_e2e": mape(actual_cloud.ravel(), pred_cloud.ravel()),
        "mape_edge_e2e": mape(actual_edge, pred_edge),
        "mape_comp_cloud": mape(test["comp"].ravel(),
                                np.maximum(models.forest.predict(np.stack([
                                    np.repeat(test["size"], 19),
                                    np.tile(np.asarray(synthdata.MEMORY_CONFIGS_MB,
                                                       dtype=np.float64),
                                            len(test["size"]))], axis=1))
                                    .reshape(-1, 19), 1.0).ravel()),
    }


# Per-app C_max anchors: (which candidate memory to anchor on, cost
# percentile). IR/FD anchor the cheapest candidate at p80 (the priciest
# ~15% of inputs need surplus); STT anchors the *fastest* candidate at p45
# (half the inputs must fall back to slower configs or the edge), because
# STT's flat comp-vs-memory curve otherwise never makes the budget bind.
CMAX_ANCHORS = {"ir": ("min", 80.0), "fd": ("min", 80.0), "stt": ("max", 45.0)}


def derive_cmax(models: TrainedModels, train: dict, app: synthdata.AppGroundTruth,
                candidate_mems: tuple[int, ...]) -> float:
    """Pick C_max so the lat-min constraint binds like the paper's Fig. 6.

    The paper's absolute C_max values are inconsistent with the AWS pricing
    formula at the reported latencies (see DESIGN.md §2), so we derive
    C_max = 1.05 x a per-app percentile of the actual cost of an anchor
    candidate configuration over the training inputs (CMAX_ANCHORS): enough
    inputs are unaffordable at alpha = 0 to produce the paper's edge blow-up,
    while modest surplus (alpha ~ 0.02-0.03) restores cloud affordability,
    yielding the 85-99 % budget-used regime of Tables IV/V.
    """
    anchor, pctl = CMAX_ANCHORS[app.name]
    target = min(candidate_mems) if anchor == "min" else max(candidate_mems)
    mems = np.asarray(synthdata.MEMORY_CONFIGS_MB, dtype=np.float64)
    j = int(np.argmin(np.abs(mems - target)))
    costs = synthdata.billed_cost(train["comp"][:, j], mems[j])
    return float(np.percentile(costs, pctl) * 1.05)


# Best-performing configuration sets from the paper's Table IV (lat-min);
# used only to anchor the C_max derivation. Experiment harnesses in Rust
# carry the full table sets.
LATMIN_BEST_SETS = {
    "ir": (1408, 1664, 2944),
    "fd": (1536, 1664, 2048),
    "stt": (1152, 1280, 1664),
}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    # CRITICAL: the default printer elides large constants as `{...}`, which
    # the downstream HLO text parser silently accepts as garbage — the trained
    # tree tables would never reach the Rust runtime. Print them in full.
    opts = xc._xla.HloPrintOptions.short_parsable()
    opts.print_large_constants = True
    return comp.get_hlo_module().to_string(opts)


# Pallas batch-block per artifact batch size (block-size sweep, §Perf):
# the b1 request path is fastest with small blocks; bulk scoring prefers 64.
KERNEL_BLOCK_B = {1: 32, 64: 64}


def lower_app(models: TrainedModels, out_dir: str) -> dict:
    paths = {}
    for b in BATCH_SIZES:
        fn = make_predict_fn(models, block_b=KERNEL_BLOCK_B.get(b, 64))
        spec = jax.ShapeDtypeStruct((b,), np.float32)
        lowered = jax.jit(fn).lower(spec)
        text = to_hlo_text(lowered)
        name = f"{models.app}_b{b}.hlo.txt"
        with open(os.path.join(out_dir, name), "w") as f:
            f.write(text)
        paths[f"b{b}"] = name
    return paths


def write_eval_csv(app: synthdata.AppGroundTruth, path: str) -> None:
    rng = np.random.default_rng(EVAL_SEED + hash(app.name) % 1000)
    ds = synthdata.sample_dataset(app, app.n_eval, rng)
    cols = (["size", "bytes", "upld"]
            + [f"comp_{m}" for m in synthdata.MEMORY_CONFIGS_MB]
            + ["start_w", "start_c", "store", "edge_comp", "iotup", "edge_store"])
    with open(path, "w") as f:
        f.write(",".join(cols) + "\n")
        for i in range(app.n_eval):
            row = ([ds["size"][i], ds["bytes"][i], ds["upld"][i]]
                   + list(ds["comp"][i])
                   + [ds["start_w"][i], ds["start_c"][i], ds["store"][i],
                      ds["edge_comp"][i], ds["iotup"][i], ds["edge_store"][i]])
            f.write(",".join(f"{v:.6f}" for v in row) + "\n")


def app_meta(app: synthdata.AppGroundTruth, models: TrainedModels,
             train: dict, metrics: dict, artifact_paths: dict) -> dict:
    cmax = derive_cmax(models, train, app, LATMIN_BEST_SETS[app.name])
    return {
        "size_unit": app.size_unit,
        "arrival_rate_per_s": app.arrival_rate_per_s,
        "deadline_ms": app.deadline_ms,
        "alpha": app.alpha,
        "cmax": cmax,
        "n_train": app.n_train,
        "n_eval": app.n_eval,
        "ground_truth": dataclasses.asdict(app),
        "models": {
            "theta": list(models.theta),
            "phi": list(models.phi),
            "bytes_per_unit": models.bytes_per_unit,
            "forest": models.forest.to_flat(),
            "start_warm_mean": models.start_warm_mean,
            "start_warm_sigma": app.start_warm_sigma,
            "start_cold_mean": models.start_cold_mean,
            "start_cold_sigma": app.start_cold_sigma,
            "store_mean": models.store_mean,
            "store_sigma": app.store_sigma,
            "iotup_mean": models.iotup_mean,
            "iotup_sigma": app.iotup_sigma,
            "edge_store_mean": models.edge_store_mean,
            "edge_store_sigma": app.edge_store_sigma,
        },
        "metrics": metrics,
        "table1": {
            "warm_start_ms": models.start_warm_mean,
            "cold_start_ms": models.start_cold_mean,
            "store_ms": models.store_mean,
            "iot_upload_ms": models.iotup_mean,
            "edge_store_ms": models.edge_store_mean,
        },
        "artifacts": artifact_paths,
        "batch_sizes": list(BATCH_SIZES),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--apps", default="ir,fd,stt")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    meta = {
        "memory_configs_mb": synthdata.MEMORY_CONFIGS_MB,
        "pricing": {
            "price_per_gb_s": synthdata.PRICE_PER_GB_S,
            "bill_quantum_ms": synthdata.BILL_QUANTUM_MS,
            "request_fee": synthdata.REQUEST_FEE,
        },
        "cpu_knee_mb": synthdata.CPU_KNEE_MB,
        "cpu_exp_below": synthdata.CPU_EXP_BELOW,
        "cpu_exp_above": synthdata.CPU_EXP_ABOVE,
        "tidl_mean_ms": synthdata.TIDL_MEAN_MS,
        "tidl_sigma_ms": synthdata.TIDL_SIGMA_MS,
        "train_seed": TRAIN_SEED,
        "eval_seed": EVAL_SEED,
        "apps": {},
    }

    for name in args.apps.split(","):
        app = synthdata.GROUND_TRUTH[name]
        print(f"[aot] {name}: training on {app.n_train} synthetic inputs ...")
        models, train, test = train_app(app)
        metrics = evaluate(models, test)
        print(f"[aot] {name}: MAPE cloud e2e = {metrics['mape_cloud_e2e']:.2f}%  "
              f"edge e2e = {metrics['mape_edge_e2e']:.2f}%")
        print(f"[aot] {name}: lowering predictor (B={BATCH_SIZES}) ...")
        paths = lower_app(models, args.out)
        write_eval_csv(app, os.path.join(args.out, f"{name}_eval.csv"))
        meta["apps"][name] = app_meta(app, models, train, metrics, paths)

    with open(os.path.join(args.out, "meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    print(f"[aot] wrote {args.out}/meta.json")


if __name__ == "__main__":
    main()
