"""Synthetic ground-truth generator for the AWS edge-cloud substrate.

The paper trains its performance models on measurements collected from AWS
Lambda / Greengrass (IR, FD, STT applications).  That testbed is unavailable,
so this module implements a *generative ground truth*: per-application latency
component distributions calibrated so that

  * component means match the paper's Table I,
  * model MAPE ordering matches Table II (IR-cloud noisy, edge pipelines tight),
  * comp(k, m) is monotone decreasing in container memory m with diminishing
    returns past ~1769 MB (1 vCPU), monotone increasing in input size,
  * the cost-latency tradeoff that drives the placement decisions is preserved.

Everything is seeded and deterministic.  The same parameters are exported to
``artifacts/meta.json`` so the Rust simulator's generative path
(``rust/src/platform/latency.rs``) samples from identical distributions; a
cross-language test compares the moments.

Units: milliseconds for all latencies, bytes / pixels for sizes.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

# The 19 AWS Lambda memory configurations used throughout the paper:
# 640 MB .. 2944 MB in 128 MB steps.
MEMORY_CONFIGS_MB = [640 + 128 * i for i in range(19)]
assert len(MEMORY_CONFIGS_MB) == 19 and MEMORY_CONFIGS_MB[-1] == 2944

# AWS Lambda pricing model (paper Sec. II-A): $1.667e-6 per GB-s, billed
# duration rounded UP to the next 100 ms; flat $0.20 per 1M requests.
PRICE_PER_GB_S = 1.667e-6
BILL_QUANTUM_MS = 100.0
REQUEST_FEE = 0.20 / 1e6

# CPU share grows linearly with memory up to ~1769 MB (1 vCPU), then with
# strongly diminishing returns.  Exponents below/above the knee.
CPU_KNEE_MB = 1769.0
CPU_EXP_BELOW = 0.85
CPU_EXP_ABOVE = 0.30

APPS = ("ir", "fd", "stt")


@dataclasses.dataclass(frozen=True)
class AppGroundTruth:
    """All generative parameters for one application."""

    name: str
    # input size distribution (lognormal over `size` units)
    size_unit: str          # "pixels" or "bytes"
    size_log_mu: float
    size_log_sigma: float
    size_min: float
    size_max: float
    bytes_per_unit: float   # upload bytes per size unit (JPEG ~0.35 B/pixel)
    # cloud components
    upld_base_ms: float
    upld_per_byte_ms: float
    upld_noise_sigma: float       # multiplicative lognormal on the whole term
    start_warm_mean: float
    start_warm_sigma: float
    start_cold_mean: float
    start_cold_sigma: float
    comp_work_coeff: float        # w(k) = coeff * (size/size_scale)^size_exp
    comp_work_exp: float
    comp_size_scale: float        # 1e6 pixels or 1e3 bytes
    comp_noise_sigma: float       # multiplicative lognormal
    store_mean: float
    store_sigma: float
    # edge components
    edge_comp_base: float         # comp_e = base + slope * size
    edge_comp_slope: float
    edge_comp_noise_sigma: float
    iotup_mean: float             # <0 means "n/a" (IR posts result direct to S3)
    iotup_sigma: float
    edge_store_mean: float
    edge_store_sigma: float
    # workload arrival (for the simulator): Poisson rate, tasks per second
    arrival_rate_per_s: float
    # experiment constants
    deadline_ms: float            # delta for cost-min (paper values)
    alpha: float                  # surplus factor for lat-min (paper values)
    n_train: int
    n_eval: int


# Calibration rationale lives in DESIGN.md §6.
IR = AppGroundTruth(
    name="ir",
    size_unit="pixels",
    size_log_mu=math.log(2.5e6), size_log_sigma=0.28,
    size_min=2.0e5, size_max=6.0e6,
    bytes_per_unit=0.35,
    upld_base_ms=120.0, upld_per_byte_ms=4.0e-4, upld_noise_sigma=0.55,
    start_warm_mean=162.0, start_warm_sigma=30.0,
    start_cold_mean=741.0, start_cold_sigma=180.0,
    comp_work_coeff=350.0, comp_work_exp=0.9, comp_size_scale=1.0e6,
    comp_noise_sigma=0.55,
    store_mean=549.0, store_sigma=150.0,
    edge_comp_base=40.0, edge_comp_slope=73.0 / 1.0e6, edge_comp_noise_sigma=0.03,
    iotup_mean=-1.0, iotup_sigma=0.0,           # n/a: resized image goes direct to S3
    edge_store_mean=579.0, edge_store_sigma=28.0,
    arrival_rate_per_s=4.0,
    deadline_ms=2700.0, alpha=0.02,
    n_train=1400, n_eval=600,
)

FD = AppGroundTruth(
    name="fd",
    size_unit="pixels",
    size_log_mu=math.log(2.5e6), size_log_sigma=0.28,
    size_min=2.0e5, size_max=6.0e6,
    bytes_per_unit=0.25,
    upld_base_ms=120.0, upld_per_byte_ms=4.0e-4, upld_noise_sigma=0.18,
    start_warm_mean=163.0, start_warm_sigma=30.0,
    start_cold_mean=1500.0, start_cold_sigma=250.0,
    comp_work_coeff=260.0, comp_work_exp=1.0, comp_size_scale=1.0e6,
    comp_noise_sigma=0.30,
    store_mean=584.0, store_sigma=150.0,
    edge_comp_base=500.0, edge_comp_slope=3000.0 / 1.0e6, edge_comp_noise_sigma=0.05,
    iotup_mean=25.0, iotup_sigma=6.0,
    edge_store_mean=583.0, edge_store_sigma=25.0,
    arrival_rate_per_s=4.0,
    deadline_ms=4500.0, alpha=0.02,
    n_train=1400, n_eval=600,
)

STT = AppGroundTruth(
    name="stt",
    size_unit="bytes",
    size_log_mu=math.log(45.0e3), size_log_sigma=0.40,
    size_min=4.0e3, size_max=4.0e5,
    bytes_per_unit=1.0,
    upld_base_ms=120.0, upld_per_byte_ms=4.0e-4, upld_noise_sigma=0.12,
    start_warm_mean=145.0, start_warm_sigma=28.0,
    start_cold_mean=1404.0, start_cold_sigma=230.0,
    comp_work_coeff=34.0, comp_work_exp=1.0, comp_size_scale=1.0e3,
    comp_noise_sigma=0.16,
    store_mean=533.0, store_sigma=260.0,
    edge_comp_base=300.0, edge_comp_slope=112.0 / 1.0e3, edge_comp_noise_sigma=0.12,
    iotup_mean=27.0, iotup_sigma=6.0,
    edge_store_mean=579.0, edge_store_sigma=60.0,
    arrival_rate_per_s=0.1,
    deadline_ms=5500.0, alpha=0.03,
    n_train=3400, n_eval=600,
)

GROUND_TRUTH = {"ir": IR, "fd": FD, "stt": STT}

# Container idle lifetime (paper: T_idl ~= 27 minutes, cf. Wang et al.).
TIDL_MEAN_MS = 27.0 * 60.0 * 1000.0
TIDL_SIGMA_MS = 2.0 * 60.0 * 1000.0


def cpu_speed_factor(mem_mb: np.ndarray | float) -> np.ndarray | float:
    """Relative compute-time multiplier for a container with `mem_mb` memory.

    1.0 at the 1-vCPU knee (1769 MB); >1 below (slower), <1 above with
    diminishing returns.
    """
    m = np.asarray(mem_mb, dtype=np.float64)
    below = (CPU_KNEE_MB / m) ** CPU_EXP_BELOW
    above = (CPU_KNEE_MB / m) ** CPU_EXP_ABOVE
    return np.where(m <= CPU_KNEE_MB, below, above)


def base_work_ms(app: AppGroundTruth, size: np.ndarray) -> np.ndarray:
    """Noise-free compute work w(k) at the 1-vCPU knee."""
    return app.comp_work_coeff * (np.asarray(size, dtype=np.float64)
                                  / app.comp_size_scale) ** app.comp_work_exp


def billed_cost(comp_ms: np.ndarray, mem_mb: np.ndarray) -> np.ndarray:
    """AWS cost of a function execution: ceil-to-100ms GB-s price + request fee."""
    billed_s = np.ceil(np.maximum(comp_ms, 1.0) / BILL_QUANTUM_MS) * (BILL_QUANTUM_MS / 1e3)
    return PRICE_PER_GB_S * (np.asarray(mem_mb, dtype=np.float64) / 1024.0) * billed_s + REQUEST_FEE


def _quantize(x: np.ndarray, q: float) -> np.ndarray:
    return np.maximum(np.round(x / q) * q, 0.0)


def sample_sizes(app: AppGroundTruth, n: int, rng: np.random.Generator) -> np.ndarray:
    s = rng.lognormal(app.size_log_mu, app.size_log_sigma, size=n)
    return np.clip(s, app.size_min, app.size_max)


def sample_dataset(app: AppGroundTruth, n: int, rng: np.random.Generator) -> dict:
    """Draw a full measurement table: n inputs x (19 cloud configs + edge).

    Mirrors the paper's data collection: warm-start cloud runs for every
    config, edge runs, plus per-config cold-start samples.
    Returns a dict of numpy arrays.
    """
    size = sample_sizes(app, n, rng)
    bytes_ = size * app.bytes_per_unit
    mems = np.asarray(MEMORY_CONFIGS_MB, dtype=np.float64)

    upld = (app.upld_base_ms + app.upld_per_byte_ms * bytes_) * rng.lognormal(
        0.0, app.upld_noise_sigma, size=n)
    # comp[n, 19]
    work = base_work_ms(app, size)[:, None]
    speed = cpu_speed_factor(mems)[None, :]
    comp = work * speed * rng.lognormal(0.0, app.comp_noise_sigma, size=(n, 19))
    comp = np.maximum(comp, 1.0)
    start_w = np.maximum(rng.normal(app.start_warm_mean, app.start_warm_sigma, size=n), 5.0)
    start_c = np.maximum(rng.normal(app.start_cold_mean, app.start_cold_sigma, size=n), 50.0)
    store = _quantize(rng.normal(app.store_mean, app.store_sigma, size=n), 100.0)

    edge_comp = (app.edge_comp_base + app.edge_comp_slope * size) * rng.lognormal(
        0.0, app.edge_comp_noise_sigma, size=n)
    if app.iotup_mean >= 0:
        iotup = np.maximum(rng.normal(app.iotup_mean, app.iotup_sigma, size=n), 0.0)
    else:
        iotup = np.zeros(n)
    edge_store = _quantize(rng.normal(app.edge_store_mean, app.edge_store_sigma, size=n), 100.0)

    return {
        "size": size, "bytes": bytes_, "upld": upld, "comp": comp,
        "start_w": start_w, "start_c": start_c, "store": store,
        "edge_comp": edge_comp, "iotup": iotup, "edge_store": edge_store,
    }


def e2e_cloud_warm(ds: dict) -> np.ndarray:
    """End-to-end warm-start cloud latency per (input, config): Eqn. (1)."""
    return (ds["upld"][:, None] + ds["start_w"][:, None] + ds["comp"]
            + ds["store"][:, None])


def e2e_edge(ds: dict) -> np.ndarray:
    """End-to-end edge latency per input (no queue wait): Eqn. (2)."""
    return ds["edge_comp"] + ds["iotup"] + ds["edge_store"]


def train_test_split(ds: dict, train_frac: float, rng: np.random.Generator):
    n = len(ds["size"])
    idx = rng.permutation(n)
    cut = int(n * train_frac)
    tr_i, te_i = idx[:cut], idx[cut:]
    take = lambda i: {k: v[i] for k, v in ds.items()}
    return take(tr_i), take(te_i)
