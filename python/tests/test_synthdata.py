"""Tests for the synthetic AWS ground truth: calibration against the paper's
Table I, structural properties the placement logic depends on, and the
pricing model."""

from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import synthdata as sd


@pytest.fixture(scope="module")
def datasets():
    rng = np.random.default_rng(123)
    return {name: sd.sample_dataset(app, 2000, rng)
            for name, app in sd.GROUND_TRUTH.items()}


def test_memory_configs_match_paper():
    assert len(sd.MEMORY_CONFIGS_MB) == 19
    assert sd.MEMORY_CONFIGS_MB[0] == 640
    assert sd.MEMORY_CONFIGS_MB[-1] == 2944
    assert 1536 in sd.MEMORY_CONFIGS_MB and 2048 in sd.MEMORY_CONFIGS_MB


# Paper Table I component means (ms).
TABLE1 = {
    "ir": {"start_w": 162, "start_c": 741, "store": 549, "edge_store": 579},
    "fd": {"start_w": 163, "start_c": 1500, "store": 584, "iotup": 25, "edge_store": 583},
    "stt": {"start_w": 145, "start_c": 1404, "store": 533, "iotup": 27, "edge_store": 579},
}


@pytest.mark.parametrize("app", sd.APPS)
def test_table1_component_means(app, datasets):
    ds = datasets[app]
    want = TABLE1[app]
    assert ds["start_w"].mean() == pytest.approx(want["start_w"], rel=0.05)
    assert ds["start_c"].mean() == pytest.approx(want["start_c"], rel=0.05)
    assert ds["store"].mean() == pytest.approx(want["store"], rel=0.08)
    assert ds["edge_store"].mean() == pytest.approx(want["edge_store"], rel=0.08)
    if "iotup" in want:
        assert ds["iotup"].mean() == pytest.approx(want["iotup"], rel=0.15)
    else:
        assert (ds["iotup"] == 0).all()  # IR: result goes direct to S3


@pytest.mark.parametrize("app", sd.APPS)
def test_comp_monotone_decreasing_in_memory(app):
    """Noise-free compute time must strictly decrease with container memory."""
    gt = sd.GROUND_TRUTH[app]
    mems = np.asarray(sd.MEMORY_CONFIGS_MB, dtype=np.float64)
    speed = sd.cpu_speed_factor(mems)
    assert (np.diff(speed) < 0).all()
    # and the knee gives diminishing returns: speedup below knee > above knee
    below = speed[0] / speed[8]     # 640 -> 1664
    above = speed[10] / speed[18]   # 1920 -> 2944
    assert below > above


@pytest.mark.parametrize("app", sd.APPS)
def test_comp_monotone_increasing_in_size(app):
    gt = sd.GROUND_TRUTH[app]
    sizes = np.linspace(gt.size_min, gt.size_max, 50)
    w = sd.base_work_ms(gt, sizes)
    assert (np.diff(w) > 0).all()


def test_cost_latency_tradeoff_exists(datasets):
    """The cheapest configuration must not be the fastest (else placement is
    trivial): check mean comp and mean cost orderings disagree."""
    ds = datasets["fd"]
    mems = np.asarray(sd.MEMORY_CONFIGS_MB, dtype=np.float64)
    mean_comp = ds["comp"].mean(axis=0)
    mean_cost = sd.billed_cost(ds["comp"], mems[None, :]).mean(axis=0)
    assert np.argmin(mean_comp) != np.argmin(mean_cost)
    # fastest is the largest memory; cheapest is a small/mid memory
    assert np.argmin(mean_comp) == len(mems) - 1
    assert np.argmin(mean_cost) < len(mems) // 2


def test_billed_cost_quantization():
    # 98 ms -> billed as 100 ms; 101 ms -> billed as 200 ms (paper example)
    c98 = sd.billed_cost(np.array([98.0]), np.array([1024.0]))[0]
    c100 = sd.billed_cost(np.array([100.0]), np.array([1024.0]))[0]
    c101 = sd.billed_cost(np.array([101.0]), np.array([1024.0]))[0]
    assert c98 == pytest.approx(c100)
    assert c101 == pytest.approx(2 * c100 - sd.REQUEST_FEE)


@settings(max_examples=30, deadline=None)
@given(ms=st.floats(1.0, 1e5), mem=st.sampled_from(sd.MEMORY_CONFIGS_MB))
def test_billed_cost_monotone_and_positive(ms, mem):
    c = sd.billed_cost(np.array([ms]), np.array([float(mem)]))[0]
    c2 = sd.billed_cost(np.array([ms + 100.0]), np.array([float(mem)]))[0]
    assert c > 0
    assert c2 > c


def test_edge_queue_stability_constants():
    """IR edge service must be stable at 4 req/s; FD must NOT be (the paper's
    edge-only blow-up depends on it)."""
    ir, fd, stt = sd.IR, sd.FD, sd.STT
    ir_mean_comp = ir.edge_comp_base + ir.edge_comp_slope * np.exp(
        ir.size_log_mu + ir.size_log_sigma ** 2 / 2)
    fd_mean_comp = fd.edge_comp_base + fd.edge_comp_slope * np.exp(
        fd.size_log_mu + fd.size_log_sigma ** 2 / 2)
    stt_mean_comp = stt.edge_comp_base + stt.edge_comp_slope * np.exp(
        stt.size_log_mu + stt.size_log_sigma ** 2 / 2)
    assert ir_mean_comp < 1000.0 / ir.arrival_rate_per_s      # stable
    assert fd_mean_comp > 3 * 1000.0 / fd.arrival_rate_per_s  # heavily unstable
    assert stt_mean_comp < 1000.0 / stt.arrival_rate_per_s    # stable


def test_stt_edge_feasible_near_deadline():
    """STT edge e2e must straddle the 5.5 s deadline so delta sweeps move
    executions between edge and cloud (paper Fig. 5)."""
    stt = sd.STT
    mean_comp = stt.edge_comp_base + stt.edge_comp_slope * np.exp(
        stt.size_log_mu + stt.size_log_sigma ** 2 / 2)
    e2e = mean_comp + stt.iotup_mean + stt.edge_store_mean
    assert 0.6 * stt.deadline_ms < e2e < 1.2 * stt.deadline_ms


def test_sample_sizes_bounds_and_determinism():
    app = sd.IR
    a = sd.sample_sizes(app, 500, np.random.default_rng(9))
    b = sd.sample_sizes(app, 500, np.random.default_rng(9))
    np.testing.assert_array_equal(a, b)
    assert a.min() >= app.size_min and a.max() <= app.size_max


def test_train_test_split_disjoint_and_complete():
    rng = np.random.default_rng(10)
    ds = sd.sample_dataset(sd.STT, 300, rng)
    tr, te = sd.train_test_split(ds, 0.8, rng)
    assert len(tr["size"]) == 240 and len(te["size"]) == 60
    merged = np.sort(np.concatenate([tr["size"], te["size"]]))
    np.testing.assert_array_equal(merged, np.sort(ds["size"]))


def test_e2e_formulas_match_eqn1_eqn2():
    rng = np.random.default_rng(11)
    ds = sd.sample_dataset(sd.FD, 10, rng)
    cloud = sd.e2e_cloud_warm(ds)
    assert cloud.shape == (10, 19)
    np.testing.assert_allclose(
        cloud[3, 7],
        ds["upld"][3] + ds["start_w"][3] + ds["comp"][3, 7] + ds["store"][3])
    edge = sd.e2e_edge(ds)
    np.testing.assert_allclose(
        edge[5], ds["edge_comp"][5] + ds["iotup"][5] + ds["edge_store"][5])
