"""Tests for the L2 prediction graph: shapes, in-graph billing parity with
the reference pricing model, numpy-vs-jax path agreement, and lowering."""

from __future__ import annotations

import numpy as np
import pytest

import jax

from compile import synthdata as sd
from compile.aot import evaluate, to_hlo_text, train_app
from compile.model import make_predict_fn


@pytest.fixture(scope="module")
def fd_models():
    models, train, test = train_app(sd.FD)
    return models, train, test


def test_predict_fn_shapes(fd_models):
    models, _, _ = fd_models
    fn = make_predict_fn(models)
    sizes = np.array([1e6, 3e6, 8e6, 2e6], np.float32)
    upld, comp, comp_edge, cost = fn(sizes)
    assert upld.shape == (4,)
    assert comp.shape == (4, 19)
    assert comp_edge.shape == (4,)
    assert cost.shape == (4, 19)


def test_predict_jax_matches_numpy_path(fd_models):
    """The jitted graph (Pallas kernel inside) must agree with the pure-numpy
    TrainedModels path used at evaluation time."""
    models, _, test = fd_models
    sizes = test["size"][:32].astype(np.float32)
    fn = jax.jit(make_predict_fn(models))
    upld, comp, comp_edge, _ = fn(sizes)
    want_cloud = models.predict_cloud_e2e_warm(sizes)
    got_cloud = (np.asarray(upld)[:, None] + models.start_warm_mean
                 + np.asarray(comp) + models.store_mean)
    np.testing.assert_allclose(got_cloud, want_cloud, rtol=2e-3)
    want_edge = models.predict_edge_e2e(sizes)
    got_edge = np.asarray(comp_edge) + models.edge_overhead_ms()
    np.testing.assert_allclose(got_edge, want_edge, rtol=2e-3)


def test_ingraph_billing_matches_reference(fd_models):
    models, _, _ = fd_models
    fn = make_predict_fn(models)
    sizes = np.array([5e5, 2.5e6, 1.1e7], np.float32)
    _, comp, _, cost = fn(sizes)
    comp = np.asarray(comp, np.float64)
    mems = np.asarray(sd.MEMORY_CONFIGS_MB, np.float64)
    want = sd.billed_cost(comp, mems[None, :])
    np.testing.assert_allclose(np.asarray(cost), want, rtol=1e-5)


def test_predicted_comp_mostly_monotone_in_memory(fd_models):
    """The learned forest should recover comp decreasing in memory (allow a
    few local inversions from binning)."""
    models, _, _ = fd_models
    fn = make_predict_fn(models)
    _, comp, _, _ = fn(np.array([2.5e6], np.float32))
    comp = np.asarray(comp)[0]
    inversions = int((np.diff(comp) > 0).sum())
    assert inversions <= 6
    assert comp[0] > comp[-1] * 1.5  # 640 MB much slower than 2944 MB


def test_mape_metrics_close_to_table2(fd_models):
    models, _, test = fd_models
    m = evaluate(models, test)
    # paper Table II FD: cloud 13.24, edge 3.78 — allow a band
    assert 9.0 < m["mape_cloud_e2e"] < 18.0
    assert 1.5 < m["mape_edge_e2e"] < 7.0


def test_lowering_emits_hlo_text(fd_models):
    models, _, _ = fd_models
    fn = make_predict_fn(models)
    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((1,), np.float32))
    text = to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "f32[1,19]" in text        # comp/cost outputs
    # forest tables embedded as constants: thresholds as [T, NI]; the
    # constant-folded feature masks as pred[T, NI]; leaf columns sliced to
    # 8 x f32[T] by the select-tree kernel
    assert "f32[100,7]" in text
    assert "pred[100,7]" in text
    assert text.count("f32[100]") >= 8


def test_lowered_graph_executes_same_as_eager(fd_models):
    """Sanity: jit(fn) == fn elementwise (XLA compile path vs trace path)."""
    models, _, _ = fd_models
    fn = make_predict_fn(models)
    sizes = np.array([1.5e6] * 8, np.float32)
    eager = fn(sizes)
    jitted = jax.jit(fn)(sizes)
    for a, b in zip(eager, jitted):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
