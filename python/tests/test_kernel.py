"""Pallas forest-evaluation kernel vs the pure-jnp oracle and the numpy
training-time reference — the core L1 correctness signal.

Hypothesis sweeps batch sizes, tree counts, depths, block sizes and feature
dimensions; dedicated cases cover degenerate trees (dead branches, +inf
thresholds), non-divisible grid tiling, and dtype handling.
"""

from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.gbrt import forest_eval
from compile.kernels.ref import forest_eval_ref
from compile.training import GbrtForest, fit_gbrt


def random_forest(rng, n_trees, depth, n_feat, dead_fraction=0.0):
    n_internal = 2 ** depth - 1
    n_leaf = 2 ** depth
    feat = rng.integers(0, n_feat, size=(n_trees, n_internal)).astype(np.int32)
    thresh = rng.normal(0, 2, size=(n_trees, n_internal)).astype(np.float32)
    if dead_fraction > 0:
        dead = rng.random((n_trees, n_internal)) < dead_fraction
        thresh = np.where(dead, np.float32(np.inf), thresh)
        feat = np.where(dead, np.int32(0), feat)
    leaf = rng.normal(0, 3, size=(n_trees, n_leaf)).astype(np.float32)
    return feat, thresh, leaf


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 97),
    n_trees=st.integers(1, 40),
    depth=st.integers(1, 5),
    n_feat=st.integers(1, 4),
    block_b=st.sampled_from([1, 7, 32, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_ref_hypothesis(b, n_trees, depth, n_feat, block_b, seed):
    rng = np.random.default_rng(seed)
    feat, thresh, leaf = random_forest(rng, n_trees, depth, n_feat)
    x = rng.normal(0, 2, size=(b, n_feat)).astype(np.float32)
    base, lr = float(rng.normal()), float(rng.uniform(0.01, 1.0))
    got = np.asarray(forest_eval(x, feat, thresh, leaf, base=base,
                                 learning_rate=lr, block_b=block_b))
    want = np.asarray(forest_eval_ref(x, feat, thresh, leaf, base=base,
                                      learning_rate=lr))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 64),
    depth=st.integers(1, 4),
    dead=st.floats(0.1, 0.9),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_degenerate_trees(b, depth, dead, seed):
    """Dead branches (+inf thresholds) must route left and stay finite."""
    rng = np.random.default_rng(seed)
    feat, thresh, leaf = random_forest(rng, 10, depth, 2, dead_fraction=dead)
    x = rng.normal(0, 2, size=(b, 2)).astype(np.float32)
    got = np.asarray(forest_eval(x, feat, thresh, leaf, base=0.0,
                                 learning_rate=0.5))
    want = np.asarray(forest_eval_ref(x, feat, thresh, leaf, base=0.0,
                                      learning_rate=0.5))
    assert np.isfinite(got).all()
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_kernel_matches_numpy_trained_forest():
    """Kernel vs the numpy GbrtForest.predict on a real trained forest."""
    rng = np.random.default_rng(3)
    x = rng.uniform(0, 8, size=(400, 2))
    y = 10 * np.sin(x[:, 0]) + x[:, 1] ** 2
    forest = fit_gbrt(x, y, n_trees=50, depth=3, seed=4)
    want = forest.predict(x)
    got = np.asarray(forest_eval(x.astype(np.float32), forest.feat,
                                 forest.thresh, forest.leaf,
                                 base=forest.base,
                                 learning_rate=forest.learning_rate))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-3)


def test_kernel_single_sample_single_tree():
    feat = np.array([[0]], dtype=np.int32)
    thresh = np.array([[1.5]], dtype=np.float32)
    leaf = np.array([[10.0, 20.0]], dtype=np.float32)
    lo = np.asarray(forest_eval(np.array([[1.0]], np.float32), feat, thresh,
                                leaf, base=1.0, learning_rate=1.0))
    hi = np.asarray(forest_eval(np.array([[2.0]], np.float32), feat, thresh,
                                leaf, base=1.0, learning_rate=1.0))
    assert lo[0] == pytest.approx(11.0)
    assert hi[0] == pytest.approx(21.0)


def test_kernel_threshold_boundary_goes_right():
    """Descent rule is x[f] >= t (ties go right), matching training/ref."""
    feat = np.array([[0]], dtype=np.int32)
    thresh = np.array([[2.0]], dtype=np.float32)
    leaf = np.array([[-1.0, +1.0]], dtype=np.float32)
    out = np.asarray(forest_eval(np.array([[2.0]], np.float32), feat, thresh,
                                 leaf, base=0.0, learning_rate=1.0))
    assert out[0] == pytest.approx(1.0)


def test_kernel_padding_not_leaked():
    """B not divisible by block_b: padded rows must not alter real outputs."""
    rng = np.random.default_rng(11)
    feat, thresh, leaf = random_forest(rng, 8, 3, 2)
    x = rng.normal(size=(13, 2)).astype(np.float32)
    a = np.asarray(forest_eval(x, feat, thresh, leaf, base=0.0,
                               learning_rate=1.0, block_b=8))
    b = np.asarray(forest_eval(x, feat, thresh, leaf, base=0.0,
                               learning_rate=1.0, block_b=13))
    np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)
    assert a.shape == (13,)


def test_kernel_rejects_bad_tree_shape():
    feat = np.zeros((2, 6), dtype=np.int32)      # 6 is not 2^D - 1
    thresh = np.zeros((2, 6), dtype=np.float32)
    leaf = np.zeros((2, 7), dtype=np.float32)
    with pytest.raises(AssertionError):
        forest_eval(np.zeros((1, 2), np.float32), feat, thresh, leaf,
                    base=0.0, learning_rate=1.0)
