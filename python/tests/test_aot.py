"""End-to-end tests of the AOT pipeline: artifact files, meta.json schema,
eval CSV layout, and the C_max derivation."""

from __future__ import annotations

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from compile import synthdata as sd
from compile.aot import (BATCH_SIZES, LATMIN_BEST_SETS, derive_cmax, train_app,
                         write_eval_csv)

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.fixture(scope="module")
def built_artifacts():
    """Use the checked-out artifacts if present, else build them."""
    meta_path = os.path.join(ART, "meta.json")
    if not os.path.exists(meta_path):
        subprocess.run([sys.executable, "-m", "compile.aot", "--out", ART],
                       check=True,
                       cwd=os.path.join(os.path.dirname(__file__), ".."))
    with open(meta_path) as f:
        return json.load(f)


def test_meta_schema(built_artifacts):
    meta = built_artifacts
    assert meta["memory_configs_mb"] == sd.MEMORY_CONFIGS_MB
    assert set(meta["apps"]) == {"ir", "fd", "stt"}
    for name, app in meta["apps"].items():
        m = app["models"]
        assert len(m["theta"]) == 2 and len(m["phi"]) == 2
        forest = m["forest"]
        ni = 2 ** forest["depth"] - 1
        assert len(forest["feat"]) == forest["n_trees"] * ni
        assert len(forest["leaf"]) == forest["n_trees"] * 2 ** forest["depth"]
        assert app["deadline_ms"] > 0 and app["cmax"] > 0
        assert 0.0 <= app["alpha"] <= 1.0


def test_hlo_artifacts_exist_and_are_text(built_artifacts):
    for name, app in built_artifacts["apps"].items():
        for b in BATCH_SIZES:
            path = os.path.join(ART, app["artifacts"][f"b{b}"])
            with open(path) as f:
                head = f.read(64)
            assert head.startswith("HloModule"), path


def test_eval_csv_layout(built_artifacts):
    for name, app in built_artifacts["apps"].items():
        path = os.path.join(ART, f"{name}_eval.csv")
        with open(path) as f:
            header = f.readline().strip().split(",")
            rows = f.readlines()
        assert header[:3] == ["size", "bytes", "upld"]
        assert len([c for c in header if c.startswith("comp_")]) == 19
        assert len(rows) == app["n_eval"]
        first = [float(v) for v in rows[0].split(",")]
        assert len(first) == len(header)
        assert all(np.isfinite(first))


def test_eval_csv_deterministic(tmp_path):
    p1, p2 = tmp_path / "a.csv", tmp_path / "b.csv"
    write_eval_csv(sd.IR, str(p1))
    write_eval_csv(sd.IR, str(p2))
    assert p1.read_text() == p2.read_text()


def test_cmax_binds_for_median_but_not_all():
    """C_max must sit inside the cost distribution of the cheapest candidate
    config: some inputs affordable, some not (else alpha has no effect)."""
    for name, app in sd.GROUND_TRUTH.items():
        models, train, _ = train_app(app)
        cmax = derive_cmax(models, train, app, LATMIN_BEST_SETS[name])
        mems = np.asarray(sd.MEMORY_CONFIGS_MB, dtype=np.float64)
        j = int(np.argmin(np.abs(mems - min(LATMIN_BEST_SETS[name]))))
        costs = sd.billed_cost(train["comp"][:, j], mems[j])
        frac_affordable = float((costs <= cmax).mean())
        assert 0.3 < frac_affordable < 0.95, (name, frac_affordable)


def test_table1_values_recorded(built_artifacts):
    t1 = built_artifacts["apps"]["fd"]["table1"]
    assert t1["warm_start_ms"] == pytest.approx(163, rel=0.05)
    assert t1["cold_start_ms"] == pytest.approx(1500, rel=0.05)
    ir = built_artifacts["apps"]["ir"]["table1"]
    assert ir["iot_upload_ms"] == -1.0  # n/a in the paper's Table I
