"""Tests for the numpy estimators (OLS, ridge, GBRT) in compile.training."""

from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.training import GbrtForest, fit_gbrt, fit_ols, fit_ridge, mape


def test_ols_recovers_exact_line():
    x = np.linspace(0, 10, 50)
    y = 3.5 + 2.25 * x
    b0, b1 = fit_ols(x, y)
    assert b0 == pytest.approx(3.5, abs=1e-9)
    assert b1 == pytest.approx(2.25, abs=1e-9)


def test_ols_with_noise_close():
    rng = np.random.default_rng(0)
    x = rng.uniform(0, 100, 2000)
    y = -4.0 + 0.7 * x + rng.normal(0, 1.0, 2000)
    b0, b1 = fit_ols(x, y)
    assert b0 == pytest.approx(-4.0, abs=0.3)
    assert b1 == pytest.approx(0.7, abs=0.01)


def test_ridge_shrinks_toward_zero_slope():
    rng = np.random.default_rng(1)
    x = rng.uniform(0, 10, 100)
    y = 5.0 + 2.0 * x + rng.normal(0, 0.1, 100)
    _, b1_small = fit_ridge(x, y, lam=1e-9)
    _, b1_big = fit_ridge(x, y, lam=1e6)
    assert b1_small == pytest.approx(2.0, abs=0.05)
    assert abs(b1_big) < abs(b1_small)


def test_ridge_lambda_zero_equals_ols():
    rng = np.random.default_rng(2)
    x = rng.uniform(0, 10, 200)
    y = 1.0 - 0.5 * x + rng.normal(0, 0.2, 200)
    assert fit_ridge(x, y, lam=1e-12) == pytest.approx(fit_ols(x, y), abs=1e-6)


def test_gbrt_beats_mean_baseline():
    rng = np.random.default_rng(3)
    x = rng.uniform(0, 10, size=(800, 2))
    y = np.sin(x[:, 0]) * 5 + np.sqrt(x[:, 1]) * 3 + rng.normal(0, 0.2, 800)
    forest = fit_gbrt(x, y, n_trees=80, depth=3, seed=5)
    pred = forest.predict(x)
    rmse = np.sqrt(((pred - y) ** 2).mean())
    rmse_mean = y.std()
    assert rmse < 0.35 * rmse_mean


def test_gbrt_generalizes_on_holdout():
    rng = np.random.default_rng(4)
    x = rng.uniform(0, 10, size=(1200, 2))
    y = x[:, 0] * x[:, 1] + rng.normal(0, 0.5, 1200)
    forest = fit_gbrt(x[:900], y[:900], n_trees=100, depth=4, seed=6)
    pred = forest.predict(x[900:])
    rmse = np.sqrt(((pred - y[900:]) ** 2).mean())
    assert rmse < 0.5 * y[900:].std()


def test_gbrt_monotone_response_on_monotone_target():
    """For a monotone target the fitted function should be ~monotone."""
    rng = np.random.default_rng(5)
    x = rng.uniform(0, 10, size=(600, 1))
    y = 3 * x[:, 0] + rng.normal(0, 0.05, 600)
    forest = fit_gbrt(x, y, n_trees=60, depth=3, seed=7)
    grid = np.linspace(0.5, 9.5, 40)[:, None]
    pred = forest.predict(grid)
    # allow tiny local wiggles but require global increase
    assert pred[-1] - pred[0] > 0.8 * (grid[-1, 0] - grid[0, 0]) * 3


def test_gbrt_constant_target_yields_base():
    x = np.random.default_rng(6).uniform(0, 1, size=(100, 2))
    y = np.full(100, 42.0)
    forest = fit_gbrt(x, y, n_trees=10, depth=2, seed=8)
    np.testing.assert_allclose(forest.predict(x), 42.0, atol=1e-6)


def test_forest_flat_export_roundtrip():
    rng = np.random.default_rng(7)
    x = rng.uniform(0, 5, size=(300, 2))
    y = x[:, 0] ** 2 - x[:, 1]
    forest = fit_gbrt(x, y, n_trees=20, depth=3, seed=9)
    flat = forest.to_flat()
    assert flat["n_trees"] == 20 and flat["depth"] == 3
    ni, nl = 2 ** 3 - 1, 2 ** 3
    rebuilt = GbrtForest(
        base=flat["base"],
        learning_rate=flat["learning_rate"],
        feat=np.array(flat["feat"], np.int32).reshape(20, ni),
        thresh=np.array(flat["thresh"], np.float32).reshape(20, ni),
        leaf=np.array(flat["leaf"], np.float32).reshape(20, nl),
    )
    np.testing.assert_allclose(rebuilt.predict(x), forest.predict(x),
                               rtol=1e-6, atol=1e-6)
    # JSON-serializable: all plain python types
    import json
    json.dumps(flat)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(30, 200), seed=st.integers(0, 10_000))
def test_gbrt_predictions_bounded_by_target_range(n, seed):
    """Tree averages can never exceed the observed target range."""
    rng = np.random.default_rng(seed)
    x = rng.uniform(-5, 5, size=(n, 2))
    y = rng.uniform(10, 20, size=n)
    forest = fit_gbrt(x, y, n_trees=30, depth=3, seed=seed)
    pred = forest.predict(x)
    assert pred.min() >= 10 - 1e-6 and pred.max() <= 20 + 1e-6


def test_mape_basic():
    assert mape(np.array([100.0, 200.0]), np.array([110.0, 180.0])) == pytest.approx(10.0)
    assert mape(np.array([50.0]), np.array([50.0])) == 0.0
